//! Hot/cold tiered backend: local [`PackedStore`] over a remote origin.
//!
//! A [`TieredStore`] serves reads from the *hot* tier — the ordinary
//! local loose + pack layout, mmap fast path and all — and fills misses
//! from the *cold* tier, a [`RemoteStore`] speaking to an origin `mgit
//! serve`. Writes always land hot (pushing to an origin is an explicit
//! `mgit push`, not a write-through).
//!
//! Policy, in order, on a `get`:
//!
//! 1. **Hot hit** — present loose or packed: served locally, zero
//!    network (`tier.hot_hits`).
//! 2. **Negative hit** — the origin previously answered a definitive
//!    `404` for this id: fail immediately without re-asking
//!    (`tier.negative_hits`). Transport errors never populate the
//!    negative cache, and a local `put` of the id clears its entry.
//! 3. **Cold fill** — fetch from the origin, write the bytes into the
//!    hot loose tier (`tier.cold_fills`), and return them. Fills are
//!    tracked in an LRU; when a byte budget is configured
//!    (`hot_bytes` in `.mgit/remote`), the oldest fills are evicted
//!    (`tier.evictions`) until the tracked total fits. Only loose
//!    *fills* are candidates — locally-authored objects and anything
//!    sealed into a pack are never evicted (pack immutability), and a
//!    fill that a later repack seals simply drops out of the
//!    evictable set.
//!
//! A successful fill also **prefetches the delta-parent chain**: the
//! fetched bytes' MGTF header names the parent object, so the resolve
//! chain a checkpoint load is about to walk is pulled in the same warm
//! pass (bounded depth, best-effort — the demand path surfaces real
//! errors). Already-hot ancestors are traversed through pack-index
//! metadata without refetching.
//!
//! See `docs/ARCHITECTURE.md` ("Remote tier") for the protocol and
//! failure semantics, and [`super::remote`] for the wire client.

use std::collections::{HashMap, HashSet, VecDeque};
use std::path::Path;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Result};

use super::format::{self, ObjectMeta};
use super::remote::{RemoteConfig, RemoteError, RemoteStore};
use super::{ObjectId, ObjectStore, PackedStore};

static OBS_HOT_HITS: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("tier.hot_hits");
static OBS_COLD_FILLS: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("tier.cold_fills");
static OBS_EVICTIONS: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("tier.evictions");
static OBS_NEGATIVE_HITS: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("tier.negative_hits");
static OBS_RESIDENT_BYTES: crate::obs::LazyGauge =
    crate::obs::LazyGauge::new("tier.resident_bytes");

/// How far a single fill's parent-chain prefetch may walk.
const PREFETCH_DEPTH: usize = 64;

/// Evictable read-through fills, LRU order (front = coldest).
#[derive(Default)]
struct FillLru {
    order: VecDeque<ObjectId>,
    sizes: HashMap<ObjectId, u64>,
    resident: u64,
}

impl FillLru {
    fn forget(&mut self, id: &ObjectId) {
        if let Some(size) = self.sizes.remove(id) {
            self.resident = self.resident.saturating_sub(size);
            if let Some(pos) = self.order.iter().position(|x| x == id) {
                self.order.remove(pos);
            }
        }
    }
}

/// What one [`TieredStore::pin_chain`] walk did.
#[derive(Debug, Default, Clone, Copy)]
pub struct PinOutcome {
    /// Objects pulled from the origin.
    pub fetched: usize,
    /// Payload bytes those fetches transferred.
    pub bytes: u64,
    /// Chain objects that were already hot.
    pub already_hot: usize,
}

/// Hot local store layered over a cold remote origin.
pub struct TieredStore {
    hot: PackedStore,
    cold: RemoteStore,
    hot_budget: Option<u64>,
    prefetch: bool,
    fills: Mutex<FillLru>,
    /// Ids the origin definitively does not hold (404).
    negative: Mutex<HashSet<ObjectId>>,
}

impl TieredStore {
    /// Open the hot tier at `dir` (same layout as [`PackedStore::open`])
    /// reading through to `cfg`'s origin. Does not dial the origin.
    pub fn open(dir: &Path, cfg: &RemoteConfig) -> Result<TieredStore> {
        Ok(TieredStore {
            hot: PackedStore::open(dir)?,
            cold: RemoteStore::connect(cfg)?,
            hot_budget: cfg.hot_bytes,
            prefetch: cfg.prefetch,
            fills: Mutex::new(FillLru::default()),
            negative: Mutex::new(HashSet::new()),
        })
    }

    /// The hot local tier (loose + packs) — what stats, fsck and repack
    /// operate on.
    pub fn hot(&self) -> &PackedStore {
        &self.hot
    }

    pub(crate) fn hot_mut(&mut self) -> &mut PackedStore {
        &mut self.hot
    }

    /// The cold-tier wire client.
    pub fn remote(&self) -> &RemoteStore {
        &self.cold
    }

    /// Mutable wire client (tests tune timeout/retry budget).
    pub fn remote_mut(&mut self) -> &mut RemoteStore {
        &mut self.cold
    }

    /// Configured fill budget in bytes (`None` = unbounded).
    pub fn hot_budget(&self) -> Option<u64> {
        self.hot_budget
    }

    /// Whether cold fills prefetch the delta-parent chain.
    pub fn prefetch_enabled(&self) -> bool {
        self.prefetch
    }

    /// Bytes currently held by evictable read-through fills.
    pub fn fill_resident_bytes(&self) -> u64 {
        self.fills.lock().unwrap().resident
    }

    /// Fetch `id` from the origin and admit it into the hot tier.
    /// A definitive origin `404` enters the negative cache.
    fn fill_one(&self, id: &ObjectId) -> Result<Vec<u8>> {
        let bytes = self.cold.fetch(id).map_err(|e| {
            if matches!(e, RemoteError::NotFound { .. }) {
                self.negative.lock().unwrap().insert(*id);
            }
            anyhow::Error::new(e)
        })?;
        OBS_COLD_FILLS.inc();
        self.admit(*id, &bytes)?;
        Ok(bytes)
    }

    /// Write a cold fill loose and enforce the byte budget, evicting the
    /// oldest fills first. The fill being admitted is never its own
    /// victim — over-budget single objects stay (budget is a target for
    /// the cache, not a hard cap on one object).
    fn admit(&self, id: ObjectId, bytes: &[u8]) -> Result<()> {
        if !self.hot.put(id, bytes)? {
            return Ok(()); // raced with another filler; already accounted
        }
        let mut lru = self.fills.lock().unwrap();
        lru.forget(&id);
        lru.order.push_back(id);
        lru.sizes.insert(id, bytes.len() as u64);
        lru.resident += bytes.len() as u64;
        if let Some(budget) = self.hot_budget {
            while lru.resident > budget {
                let Some(&victim) = lru.order.front() else { break };
                if victim == id {
                    break;
                }
                lru.forget(&victim);
                if self.hot.remove(&victim)? {
                    OBS_EVICTIONS.inc();
                }
            }
        }
        OBS_RESIDENT_BYTES.set(lru.resident as i64);
        Ok(())
    }

    /// Move a re-read fill to the warm end of the LRU.
    fn touch(&self, id: &ObjectId) {
        let mut lru = self.fills.lock().unwrap();
        if lru.sizes.contains_key(id) {
            if let Some(pos) = lru.order.iter().position(|x| x == id) {
                if let Some(v) = lru.order.remove(pos) {
                    lru.order.push_back(v);
                }
            }
        }
    }

    /// Header metadata for a hot object: loose header parse, or the pack
    /// index for sealed objects (mirrors [`super::Store::object_meta`]).
    fn hot_meta(&self, id: &ObjectId) -> Option<ObjectMeta> {
        if !self.hot.loose().contains(id) {
            if let Some(m) = self.hot.indexed_meta(id) {
                return Some(m);
            }
        }
        self.hot
            .get(id)
            .ok()
            .map(|bytes| format::TensorObject::decode_meta(&bytes))
    }

    /// Best-effort warm pass over the delta-parent chain of a just-filled
    /// object: fetching one checkpoint tensor pulls the ancestors its
    /// resolve is about to demand, over the same pooled connection.
    fn prefetch_parents(&self, first: &[u8]) {
        let mut meta = format::TensorObject::decode_meta(first);
        for _ in 0..PREFETCH_DEPTH {
            let Some(parent) = meta.parent else { break };
            if self.hot.contains(&parent) {
                match self.hot_meta(&parent) {
                    Some(m) => {
                        meta = m;
                        continue;
                    }
                    None => break,
                }
            }
            if self.negative.lock().unwrap().contains(&parent) {
                break;
            }
            match self.fill_one(&parent) {
                Ok(bytes) => meta = format::TensorObject::decode_meta(&bytes),
                Err(_) => break, // the demand path will surface real errors
            }
        }
    }

    /// Pin `id` and its entire delta-parent chain into the hot tier
    /// (`mgit fetch`). Unlike the read path this is not best-effort: any
    /// unreachable chain object is an error, so a successful pin
    /// guarantees the subtree resolves offline.
    pub fn pin_chain(&self, id: &ObjectId) -> Result<PinOutcome> {
        let mut out = PinOutcome::default();
        let mut cursor = Some(*id);
        let mut depth = 0usize;
        while let Some(id) = cursor {
            depth += 1;
            if depth > 100_000 {
                bail!("delta chain too deep (or cyclic) at {}", id.short());
            }
            let meta = if self.hot.contains(&id) {
                out.already_hot += 1;
                self.hot_meta(&id)
                    .ok_or_else(|| anyhow!("hot object {} is unreadable", id.short()))?
            } else {
                let bytes = self.fill_one(&id)?;
                out.fetched += 1;
                out.bytes += bytes.len() as u64;
                format::TensorObject::decode_meta(&bytes)
            };
            cursor = meta.parent;
        }
        Ok(out)
    }
}

impl ObjectStore for TieredStore {
    fn get(&self, id: &ObjectId) -> Result<Vec<u8>> {
        if self.hot.contains(id) {
            OBS_HOT_HITS.inc();
            self.touch(id);
            return self.hot.get(id);
        }
        if self.negative.lock().unwrap().contains(id) {
            OBS_NEGATIVE_HITS.inc();
            bail!(
                "object {} is not in the hot tier and origin {} previously \
                 answered 404 for it (negative cache)",
                id.short(),
                self.cold.url()
            );
        }
        let bytes = self.fill_one(id)?;
        if self.prefetch {
            self.prefetch_parents(&bytes);
        }
        Ok(bytes)
    }

    fn put(&self, id: ObjectId, bytes: &[u8]) -> Result<bool> {
        // A local write supersedes any stale negative knowledge.
        self.negative.lock().unwrap().remove(&id);
        self.hot.put(id, bytes)
    }

    fn contains(&self, id: &ObjectId) -> bool {
        if self.hot.contains(id) {
            return true;
        }
        if self.negative.lock().unwrap().contains(id) {
            return false;
        }
        self.cold.contains_remote(id).unwrap_or(false)
    }

    /// Hot tier only: the wire has no enumeration endpoint, and every
    /// caller of `list` (GC, fsck, stats) operates on local state.
    fn list(&self) -> Result<Vec<ObjectId>> {
        self.hot.list()
    }

    fn remove(&self, id: &ObjectId) -> Result<bool> {
        self.fills.lock().unwrap().forget(id);
        self.hot.remove(id)
    }

    /// Hot tier only (what this machine is spending).
    fn stored_bytes(&self) -> Result<u64> {
        self.hot.stored_bytes()
    }
}
