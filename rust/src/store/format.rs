//! MGTF — the self-describing binary object format for stored tensors.
//!
//! ```text
//! magic  "MGTF"                      4 bytes
//! version u8 = 1
//! enc     u8   0 = raw, 1 = delta
//! dtype   u8   tensor::DType code
//! ndim    u8
//! dims    u64 LE × ndim
//! -- if enc == delta --
//! parent  ObjectId                   32 bytes (logical hash of parent tensor)
//! eps     f32 LE                     quantization error bound
//! codec   u8                         delta::Codec code
//! nquant  u64 LE                     quantized element count (== numel)
//! -- payload --
//! raw:   dtype data, little-endian
//! delta: codec-compressed bytes of the i32 quantized delta
//! ```
//!
//! Each delta-compressed parameter is stored "as the compressed delta along
//! with a pointer to the parent layer" (paper §4); chains are resolved
//! recursively by [`crate::delta::resolve_tensor`] (or its thread-safe
//! sibling [`crate::delta::resolve_tensor_shared`]).

use std::cell::Cell;

use anyhow::{bail, Result};

use super::ObjectId;
use crate::tensor::DType;

pub const MAGIC: &[u8; 4] = b"MGTF";
pub const VERSION: u8 = 1;

thread_local! {
    /// Per-thread count of full [`TensorObject::decode`] calls — the
    /// expensive path that copies (and later decompresses) payload
    /// bytes. [`TensorObject::decode_meta`] does *not* count: it parses
    /// the fixed-size header only. The repack mark phase and fsck's
    /// orphan scan are asserted decode-free against this counter
    /// (thread-local so concurrent tests can't pollute each other).
    /// Every decode *also* bumps the process-global
    /// `store.payload_decodes` registry counter below, which is what
    /// `GET /metrics` serves.
    static PAYLOAD_DECODES: Cell<u64> = const { Cell::new(0) };
}

/// Process-wide decode counter mirrored into [`crate::obs::global`]
/// (the thread-local above stays the test oracle — thread isolation
/// keeps concurrent tests honest; the registry aggregates for ops).
static OBS_PAYLOAD_DECODES: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("store.payload_decodes");

/// This thread's cumulative count of full payload decodes.
pub fn payload_decodes() -> u64 {
    PAYLOAD_DECODES.with(|c| c.get())
}

/// What a stored object is, determinable from its header alone.
///
/// Persisted in pack index v2 entries (do not renumber) so chain
/// discovery — repack marking, fsck's orphan scan, `stats`' depth
/// histogram — can walk delta-parent edges without touching pack
/// payload bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ObjectKind {
    /// MGTF raw tensor (chain base).
    Raw,
    /// MGTF delta against a parent tensor.
    Delta,
    /// Not an MGTF object (graph JSON, arbitrary blobs).
    Opaque,
}

impl ObjectKind {
    pub fn code(self) -> u8 {
        match self {
            ObjectKind::Raw => 0,
            ObjectKind::Delta => 1,
            ObjectKind::Opaque => 2,
        }
    }

    pub fn from_code(c: u8) -> Result<ObjectKind> {
        match c {
            0 => Ok(ObjectKind::Raw),
            1 => Ok(ObjectKind::Delta),
            2 => Ok(ObjectKind::Opaque),
            _ => bail!("unknown object kind code {c}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ObjectKind::Raw => "raw",
            ObjectKind::Delta => "delta",
            ObjectKind::Opaque => "opaque",
        }
    }
}

/// Header-only view of a stored object: everything chain discovery and
/// byte accounting need, with the payload left untouched.
///
/// Produced by [`TensorObject::decode_meta`] (which parses the header of
/// the object bytes) or reconstructed from a v2 pack index entry (in
/// which case `shape`/`dtype` are `None` — the index does not persist
/// them).
#[derive(Debug, Clone, PartialEq)]
pub struct ObjectMeta {
    pub kind: ObjectKind,
    /// Delta-parent id; `None` for raw and opaque objects.
    pub parent: Option<ObjectId>,
    pub dtype: Option<DType>,
    /// Tensor shape; `None` when the meta came from a pack index.
    pub shape: Option<Vec<usize>>,
    /// Tensor element count: the shape product for header-parsed tensor
    /// objects, the persisted value for v3 pack-index answers (v3
    /// stores numel without the full shape), `None` for opaque objects
    /// and v2-index answers (which don't persist it).
    pub numel: Option<u64>,
    /// `true` when this answer came from pack-index v2+ metadata (zero
    /// object reads); `false` when the object bytes were read and
    /// header-parsed.
    pub from_index: bool,
}

impl ObjectMeta {
    /// Meta for an object known only through a pack index entry.
    /// `numel` is the index-persisted element count (v3 indexes; opaque
    /// entries persist 0, reported here as `None` — an opaque blob has
    /// no tensor elements).
    pub fn from_index(
        kind: ObjectKind,
        parent: Option<ObjectId>,
        numel: Option<u64>,
    ) -> ObjectMeta {
        let numel = match kind {
            ObjectKind::Opaque => None,
            _ => numel,
        };
        ObjectMeta { kind, parent, dtype: None, shape: None, numel, from_index: true }
    }
}

/// Parsed object header + payload.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorObject {
    Raw {
        dtype: DType,
        shape: Vec<usize>,
        payload: Vec<u8>,
    },
    Delta {
        dtype: DType,
        shape: Vec<usize>,
        parent: ObjectId,
        eps: f32,
        codec: u8,
        n_quant: usize,
        /// Grid mode (enc byte 2): parent and child both live on the
        /// quantization grid k·step; the payload stores integer grid
        /// deltas and reconstruction is (round(parent/step) − q)·step —
        /// exact for zeros on any backend (G4 sparsity preservation).
        grid: bool,
        payload: Vec<u8>,
    },
}

impl TensorObject {
    pub fn shape(&self) -> &[usize] {
        match self {
            TensorObject::Raw { shape, .. } | TensorObject::Delta { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            TensorObject::Raw { dtype, .. } | TensorObject::Delta { dtype, .. } => *dtype,
        }
    }

    /// Serialized on-disk size in bytes.
    pub fn encoded_len(&self) -> usize {
        self.encode().len()
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.push(VERSION);
        match self {
            TensorObject::Raw { dtype, shape, payload } => {
                out.push(0);
                out.push(dtype.code());
                out.push(shape.len() as u8);
                for d in shape {
                    out.extend_from_slice(&(*d as u64).to_le_bytes());
                }
                out.extend_from_slice(payload);
            }
            TensorObject::Delta { dtype, shape, parent, eps, codec, n_quant, grid, payload } => {
                out.push(if *grid { 2 } else { 1 });
                out.push(dtype.code());
                out.push(shape.len() as u8);
                for d in shape {
                    out.extend_from_slice(&(*d as u64).to_le_bytes());
                }
                out.extend_from_slice(&parent.0);
                out.extend_from_slice(&eps.to_le_bytes());
                out.push(*codec);
                out.extend_from_slice(&(*n_quant as u64).to_le_bytes());
                out.extend_from_slice(payload);
            }
        }
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<TensorObject> {
        PAYLOAD_DECODES.with(|c| c.set(c.get() + 1));
        OBS_PAYLOAD_DECODES.inc();
        let mut r = Reader { b: bytes, pos: 0 };
        let h = parse_header(&mut r)?;
        match h.enc {
            0 => Ok(TensorObject::Raw {
                dtype: h.dtype,
                shape: h.shape,
                payload: r.rest().to_vec(),
            }),
            1 | 2 => {
                let mut parent = [0u8; 32];
                parent.copy_from_slice(r.take(32)?);
                let eps = f32::from_le_bytes(r.take(4)?.try_into().unwrap());
                let codec = r.u8()?;
                let n_quant = r.u64()? as usize;
                Ok(TensorObject::Delta {
                    dtype: h.dtype,
                    shape: h.shape,
                    parent: ObjectId(parent),
                    eps,
                    codec,
                    n_quant,
                    grid: h.enc == 2,
                    payload: r.rest().to_vec(),
                })
            }
            other => bail!("unknown MGTF encoding {other}"),
        }
    }

    /// Parse only the header of `bytes`: kind, delta parent, dtype and
    /// shape — no payload copy, no decompression, and no bump of the
    /// [`payload_decodes`] counter. Shares [`parse_header`] with
    /// [`TensorObject::decode`] so the two can never drift. Anything
    /// that is not a well-formed MGTF header is reported as
    /// [`ObjectKind::Opaque`] rather than an error (the store holds
    /// opaque blobs by design).
    pub fn decode_meta(bytes: &[u8]) -> ObjectMeta {
        fn parse(bytes: &[u8]) -> Result<ObjectMeta> {
            let mut r = Reader { b: bytes, pos: 0 };
            let h = parse_header(&mut r)?;
            let numel = Some(h.shape.iter().product::<usize>() as u64);
            match h.enc {
                0 => Ok(ObjectMeta {
                    kind: ObjectKind::Raw,
                    parent: None,
                    dtype: Some(h.dtype),
                    shape: Some(h.shape),
                    numel,
                    from_index: false,
                }),
                1 | 2 => {
                    let mut parent = [0u8; 32];
                    parent.copy_from_slice(r.take(32)?);
                    Ok(ObjectMeta {
                        kind: ObjectKind::Delta,
                        parent: Some(ObjectId(parent)),
                        dtype: Some(h.dtype),
                        shape: Some(h.shape),
                        numel,
                        from_index: false,
                    })
                }
                _ => bail!("unknown encoding"),
            }
        }
        parse(bytes).unwrap_or(ObjectMeta {
            kind: ObjectKind::Opaque,
            parent: None,
            dtype: None,
            shape: None,
            numel: None,
            from_index: false,
        })
    }

    /// Outgoing object references (for GC).
    pub fn refs(&self) -> Vec<ObjectId> {
        match self {
            TensorObject::Raw { .. } => Vec::new(),
            TensorObject::Delta { parent, .. } => vec![*parent],
        }
    }
}

/// The fixed MGTF header fields shared by every encoding, parsed by
/// [`parse_header`] — the single parser behind both
/// [`TensorObject::decode`] and [`TensorObject::decode_meta`].
struct Header {
    enc: u8,
    dtype: DType,
    shape: Vec<usize>,
}

/// Parse magic, version, encoding byte, dtype and shape, leaving the
/// reader positioned at the encoding-specific fields (delta parent, …).
fn parse_header(r: &mut Reader<'_>) -> Result<Header> {
    if r.take(4)? != MAGIC {
        bail!("not an MGTF object");
    }
    let version = r.u8()?;
    if version != VERSION {
        bail!("unsupported MGTF version {version}");
    }
    let enc = r.u8()?;
    let dtype = DType::from_code(r.u8()?)?;
    let ndim = r.u8()? as usize;
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(r.u64()? as usize);
    }
    Ok(Header { enc, dtype, shape })
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("truncated MGTF object");
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn rest(&self) -> &'a [u8] {
        &self.b[self.pos..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::hash_bytes;

    #[test]
    fn raw_roundtrip() {
        let obj = TensorObject::Raw {
            dtype: DType::F32,
            shape: vec![2, 3],
            payload: vec![1, 2, 3, 4],
        };
        let bytes = obj.encode();
        assert_eq!(TensorObject::decode(&bytes).unwrap(), obj);
        assert!(obj.refs().is_empty());
    }

    #[test]
    fn delta_roundtrip() {
        let parent = hash_bytes(b"parent");
        for grid in [false, true] {
            let obj = TensorObject::Delta {
                dtype: DType::F32,
                shape: vec![8],
                parent,
                eps: 1e-4,
                codec: 2,
                n_quant: 8,
                grid,
                payload: vec![9; 17],
            };
            let bytes = obj.encode();
            let back = TensorObject::decode(&bytes).unwrap();
            assert_eq!(back, obj);
            assert_eq!(back.refs(), vec![parent]);
        }
    }

    #[test]
    fn decode_meta_matches_decode_without_counting() {
        let parent = hash_bytes(b"meta-parent");
        let raw = TensorObject::Raw {
            dtype: DType::F32,
            shape: vec![3, 5],
            payload: vec![0; 60],
        };
        let delta = TensorObject::Delta {
            dtype: DType::F32,
            shape: vec![7],
            parent,
            eps: 1e-4,
            codec: 1,
            n_quant: 7,
            grid: true,
            payload: vec![1, 2, 3],
        };
        let before = payload_decodes();
        let m = TensorObject::decode_meta(&raw.encode());
        assert_eq!(m.kind, ObjectKind::Raw);
        assert_eq!(m.parent, None);
        assert_eq!(m.shape.as_deref(), Some(&[3usize, 5][..]));
        let m = TensorObject::decode_meta(&delta.encode());
        assert_eq!(m.kind, ObjectKind::Delta);
        assert_eq!(m.parent, Some(parent));
        let m = TensorObject::decode_meta(b"not an object at all");
        assert_eq!(m.kind, ObjectKind::Opaque);
        assert_eq!(m.parent, None);
        assert_eq!(
            payload_decodes(),
            before,
            "decode_meta must not count as a payload decode"
        );
        TensorObject::decode(&raw.encode()).unwrap();
        assert_eq!(payload_decodes(), before + 1, "decode must count");
    }

    #[test]
    fn object_kind_codes_roundtrip() {
        for k in [ObjectKind::Raw, ObjectKind::Delta, ObjectKind::Opaque] {
            assert_eq!(ObjectKind::from_code(k.code()).unwrap(), k);
        }
        assert!(ObjectKind::from_code(7).is_err());
    }

    #[test]
    fn rejects_bad_input() {
        assert!(TensorObject::decode(b"nope").is_err());
        assert!(TensorObject::decode(b"MGTF").is_err());
        let mut good = TensorObject::Raw {
            dtype: DType::F32,
            shape: vec![1],
            payload: vec![0; 4],
        }
        .encode();
        good[4] = 9; // bad version
        assert!(TensorObject::decode(&good).is_err());
    }
}
