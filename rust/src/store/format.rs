//! MGTF — the self-describing binary object format for stored tensors.
//!
//! ```text
//! magic  "MGTF"                      4 bytes
//! version u8 = 1
//! enc     u8   0 = raw, 1 = delta
//! dtype   u8   tensor::DType code
//! ndim    u8
//! dims    u64 LE × ndim
//! -- if enc == delta --
//! parent  ObjectId                   32 bytes (logical hash of parent tensor)
//! eps     f32 LE                     quantization error bound
//! codec   u8                         delta::Codec code
//! nquant  u64 LE                     quantized element count (== numel)
//! -- payload --
//! raw:   dtype data, little-endian
//! delta: codec-compressed bytes of the i32 quantized delta
//! ```
//!
//! Each delta-compressed parameter is stored "as the compressed delta along
//! with a pointer to the parent layer" (paper §4); chains are resolved
//! recursively by [`crate::delta::resolve_tensor`] (or its thread-safe
//! sibling [`crate::delta::resolve_tensor_shared`]).

use anyhow::{bail, Result};

use super::ObjectId;
use crate::tensor::DType;

pub const MAGIC: &[u8; 4] = b"MGTF";
pub const VERSION: u8 = 1;

/// Parsed object header + payload.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorObject {
    Raw {
        dtype: DType,
        shape: Vec<usize>,
        payload: Vec<u8>,
    },
    Delta {
        dtype: DType,
        shape: Vec<usize>,
        parent: ObjectId,
        eps: f32,
        codec: u8,
        n_quant: usize,
        /// Grid mode (enc byte 2): parent and child both live on the
        /// quantization grid k·step; the payload stores integer grid
        /// deltas and reconstruction is (round(parent/step) − q)·step —
        /// exact for zeros on any backend (G4 sparsity preservation).
        grid: bool,
        payload: Vec<u8>,
    },
}

impl TensorObject {
    pub fn shape(&self) -> &[usize] {
        match self {
            TensorObject::Raw { shape, .. } | TensorObject::Delta { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            TensorObject::Raw { dtype, .. } | TensorObject::Delta { dtype, .. } => *dtype,
        }
    }

    /// Serialized on-disk size in bytes.
    pub fn encoded_len(&self) -> usize {
        self.encode().len()
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.push(VERSION);
        match self {
            TensorObject::Raw { dtype, shape, payload } => {
                out.push(0);
                out.push(dtype.code());
                out.push(shape.len() as u8);
                for d in shape {
                    out.extend_from_slice(&(*d as u64).to_le_bytes());
                }
                out.extend_from_slice(payload);
            }
            TensorObject::Delta { dtype, shape, parent, eps, codec, n_quant, grid, payload } => {
                out.push(if *grid { 2 } else { 1 });
                out.push(dtype.code());
                out.push(shape.len() as u8);
                for d in shape {
                    out.extend_from_slice(&(*d as u64).to_le_bytes());
                }
                out.extend_from_slice(&parent.0);
                out.extend_from_slice(&eps.to_le_bytes());
                out.push(*codec);
                out.extend_from_slice(&(*n_quant as u64).to_le_bytes());
                out.extend_from_slice(payload);
            }
        }
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<TensorObject> {
        let mut r = Reader { b: bytes, pos: 0 };
        if r.take(4)? != MAGIC {
            bail!("not an MGTF object");
        }
        let version = r.u8()?;
        if version != VERSION {
            bail!("unsupported MGTF version {version}");
        }
        let enc = r.u8()?;
        let dtype = DType::from_code(r.u8()?)?;
        let ndim = r.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u64()? as usize);
        }
        match enc {
            0 => Ok(TensorObject::Raw { dtype, shape, payload: r.rest().to_vec() }),
            1 | 2 => {
                let mut parent = [0u8; 32];
                parent.copy_from_slice(r.take(32)?);
                let eps = f32::from_le_bytes(r.take(4)?.try_into().unwrap());
                let codec = r.u8()?;
                let n_quant = r.u64()? as usize;
                Ok(TensorObject::Delta {
                    dtype,
                    shape,
                    parent: ObjectId(parent),
                    eps,
                    codec,
                    n_quant,
                    grid: enc == 2,
                    payload: r.rest().to_vec(),
                })
            }
            other => bail!("unknown MGTF encoding {other}"),
        }
    }

    /// Outgoing object references (for GC).
    pub fn refs(&self) -> Vec<ObjectId> {
        match self {
            TensorObject::Raw { .. } => Vec::new(),
            TensorObject::Delta { parent, .. } => vec![*parent],
        }
    }
}

struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("truncated MGTF object");
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn rest(&self) -> &'a [u8] {
        &self.b[self.pos..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::hash_bytes;

    #[test]
    fn raw_roundtrip() {
        let obj = TensorObject::Raw {
            dtype: DType::F32,
            shape: vec![2, 3],
            payload: vec![1, 2, 3, 4],
        };
        let bytes = obj.encode();
        assert_eq!(TensorObject::decode(&bytes).unwrap(), obj);
        assert!(obj.refs().is_empty());
    }

    #[test]
    fn delta_roundtrip() {
        let parent = hash_bytes(b"parent");
        for grid in [false, true] {
            let obj = TensorObject::Delta {
                dtype: DType::F32,
                shape: vec![8],
                parent,
                eps: 1e-4,
                codec: 2,
                n_quant: 8,
                grid,
                payload: vec![9; 17],
            };
            let bytes = obj.encode();
            let back = TensorObject::decode(&bytes).unwrap();
            assert_eq!(back, obj);
            assert_eq!(back.refs(), vec![parent]);
        }
    }

    #[test]
    fn rejects_bad_input() {
        assert!(TensorObject::decode(b"nope").is_err());
        assert!(TensorObject::decode(b"MGTF").is_err());
        let mut good = TensorObject::Raw {
            dtype: DType::F32,
            shape: vec![1],
            payload: vec![0; 4],
        }
        .encode();
        good[4] = 9; // bad version
        assert!(TensorObject::decode(&good).is_err());
    }
}
