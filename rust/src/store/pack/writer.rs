//! Streaming pack writer: objects are appended to a temp file with a
//! running SHA-256; `finish` seals the trailer, renames the pack to its
//! content hash, and writes the sidecar index.

use std::fs::File;
use std::io::Write;
use std::path::PathBuf;

use anyhow::{Context, Result};
use sha2::{Digest, Sha256};

use super::{IdxEntry, PackFile, PackIndex, PACK_MAGIC, VERSION};
use crate::store::ObjectId;

pub struct PackWriter {
    dir: PathBuf,
    tmp_path: PathBuf,
    file: File,
    hasher: Sha256,
    entries: Vec<IdxEntry>,
    offset: u64,
}

impl PackWriter {
    /// Start a new pack in `pack_dir` (created if needed). The file stays
    /// a `tmp-*.pack` until [`PackWriter::finish`] renames it.
    pub fn create(pack_dir: &std::path::Path) -> Result<PackWriter> {
        std::fs::create_dir_all(pack_dir)
            .with_context(|| format!("creating pack dir {}", pack_dir.display()))?;
        // Not `.pack`: a crash must not leave something PackedStore::open
        // would try to load as a sealed pack.
        let tmp_path = pack_dir.join(format!("tmp-{}.packtmp", std::process::id()));
        let file = File::create(&tmp_path)
            .with_context(|| format!("creating {}", tmp_path.display()))?;
        let mut w = PackWriter {
            dir: pack_dir.to_path_buf(),
            tmp_path,
            file,
            hasher: Sha256::new(),
            entries: Vec::new(),
            offset: 0,
        };
        w.write_hashed(PACK_MAGIC)?;
        w.write_hashed(&[VERSION])?;
        Ok(w)
    }

    fn write_hashed(&mut self, bytes: &[u8]) -> Result<()> {
        self.file.write_all(bytes)?;
        self.hasher.update(bytes);
        self.offset += bytes.len() as u64;
        Ok(())
    }

    /// Append one object. Ids must be unique within a pack (checked at
    /// `finish` when the index is built).
    pub fn add(&mut self, id: ObjectId, bytes: &[u8]) -> Result<()> {
        self.write_hashed(&(bytes.len() as u64).to_le_bytes())?;
        let offset = self.offset;
        self.write_hashed(bytes)?;
        self.entries.push(IdxEntry { id, offset, len: bytes.len() as u64 });
        Ok(())
    }

    pub fn object_count(&self) -> usize {
        self.entries.len()
    }

    /// Seal the pack: write the count trailer + checksum, rename to
    /// `pack-<sha256>.pack`, and write the sidecar `.idx`.
    pub fn finish(mut self) -> Result<PackFile> {
        let count = self.entries.len() as u64;
        self.write_hashed(&count.to_le_bytes())?;
        let PackWriter { dir, tmp_path, mut file, hasher, entries, .. } = self;
        let sha: [u8; 32] = hasher.finalize().into();
        file.write_all(&sha)?;
        file.sync_all()?;
        drop(file);

        let hex: String = sha.iter().map(|b| format!("{b:02x}")).collect();
        let pack_path = dir.join(format!("pack-{hex}.pack"));
        let index = PackIndex::from_entries(entries, sha)?;
        // Index first, then rename: the rename is the atomic commit point
        // (an orphaned .idx is ignored by the pack scan; a sealed pack
        // without its index would make the store unopenable).
        index.save(&PackFile::idx_path(&pack_path))?;
        std::fs::rename(&tmp_path, &pack_path)?;
        PackFile::open(&pack_path)
    }

    /// Drop the partial pack without sealing it.
    pub fn abort(self) -> Result<()> {
        drop(self.file);
        if self.tmp_path.exists() {
            std::fs::remove_file(&self.tmp_path)?;
        }
        Ok(())
    }
}
