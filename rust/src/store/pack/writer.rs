//! Streaming pack writer: objects are appended to a temp file (raw
//! framing) or a zstd stream (zstd framing) with a running SHA-256;
//! `finish` seals the trailer, renames the pack to its content hash, and
//! writes the sidecar v2 index (delta-parent/kind/depth metadata per
//! entry).
//!
//! [`PackWriter::create_chunked`] additionally runs every object
//! through the content-defined chunker ([`crate::delta::chunk`]) and
//! keeps an in-memory chunk table (fingerprint → logical offset) for
//! the pack being written. An object whose chunks largely already
//! exist earlier in the pack is stored as an `MGCR` copy/literal
//! [`recipe`](super::recipe) — cross-object byte dedup with no lineage
//! edge required — and the pack is sealed as version 3 so old readers
//! never misparse a recipe as object bytes.

use std::collections::HashMap;
use std::fs::File;
#[cfg(feature = "zstd")]
use std::io::Read;
use std::io::Write;
use std::path::PathBuf;

use anyhow::{Context, Result};
use sha2::{Digest, Sha256};

use super::recipe::{self, Recipe, RecipeOp};
use super::{
    header_len, EntryMeta, IdxEntry, PackFile, PackFraming, PackIndex, PACK_MAGIC, VERSION,
    VERSION_CHUNKED,
};
use crate::delta::chunk::{chunk_bytes, Chunk, ChunkConfig};
use crate::store::ObjectId;

/// Shared chunk copies written as recipe ops (`dedup.chunks_shared`).
static OBS_CHUNKS_SHARED: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("dedup.chunks_shared");
/// Bytes saved by storing recipes instead of inline objects
/// (`dedup.bytes_saved`).
static OBS_BYTES_SAVED: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("dedup.bytes_saved");

/// A recipe must beat the inline encoding by at least this many bytes;
/// marginal recipes are not worth the indirection on the read path.
const RECIPE_MIN_GAIN: usize = 32;

/// Chunk-dedup state for one pack being written.
struct ChunkDedup {
    cfg: ChunkConfig,
    /// Chunk fingerprint → (logical offset, length) of the first place
    /// those bytes were physically written in this pack (inline entry
    /// bytes or a recipe literal).
    table: HashMap<[u8; 32], (u64, u32)>,
    shared: u64,
    bytes_saved: u64,
    recipes: usize,
}

/// How one object will be stored, decided before any byte is written.
enum Plan {
    /// Chunking disabled: the classic path, untouched.
    Passthrough,
    /// Store inline and register these chunks for later objects.
    Inline(Vec<Chunk>),
    /// Store a recipe.
    Recipe {
        bytes: Vec<u8>,
        /// (fingerprint, offset within the recipe bytes, len) of each
        /// literal-carried chunk — registered post-write so later
        /// objects can copy from this entry's literals too.
        literals: Vec<([u8; 32], u64, u32)>,
        hits: u64,
        saved: u64,
    },
}

/// Chunk `bytes` against the table and decide inline vs. recipe.
fn plan_entry(bytes: &[u8], d: &ChunkDedup) -> Plan {
    let chunks = chunk_bytes(bytes, &d.cfg);
    let mut ops: Vec<RecipeOp> = Vec::new();
    // (fingerprint, op index, offset within that literal's data, len)
    let mut lits: Vec<([u8; 32], usize, usize, u32)> = Vec::new();
    let mut hits = 0u64;
    for c in &chunks {
        match d.table.get(&c.hash) {
            Some(&(src, len)) if len == c.len => {
                hits += 1;
                if let Some(RecipeOp::Copy { src: psrc, len: plen }) = ops.last_mut() {
                    if *psrc + *plen as u64 == src
                        && (*plen as u64 + c.len as u64) <= u32::MAX as u64
                    {
                        *plen += c.len;
                        continue;
                    }
                }
                ops.push(RecipeOp::Copy { src, len: c.len });
            }
            _ => {
                let data = &bytes[c.start..c.start + c.len as usize];
                if let Some(RecipeOp::Literal(buf)) = ops.last_mut() {
                    lits.push((c.hash, ops.len() - 1, buf.len(), c.len));
                    buf.extend_from_slice(data);
                } else {
                    ops.push(RecipeOp::Literal(data.to_vec()));
                    lits.push((c.hash, ops.len() - 1, 0, c.len));
                }
            }
        }
    }
    if hits == 0 {
        return Plan::Inline(chunks);
    }
    let r = Recipe { ulen: bytes.len() as u64, ops };
    let rlen = r.encoded_len();
    if rlen + RECIPE_MIN_GAIN >= bytes.len() {
        return Plan::Inline(chunks);
    }
    // Literal data positions within the serialized recipe, so literal
    // chunks can be registered at their final logical offsets.
    let mut op_data_start = vec![0u64; r.ops.len()];
    let mut pos = recipe::HEADER_LEN as u64;
    for (i, op) in r.ops.iter().enumerate() {
        match op {
            RecipeOp::Copy { .. } => pos += recipe::COPY_OP_LEN as u64,
            RecipeOp::Literal(data) => {
                op_data_start[i] = pos + recipe::LITERAL_OP_OVERHEAD as u64;
                pos += (recipe::LITERAL_OP_OVERHEAD + data.len()) as u64;
            }
        }
    }
    let literals = lits
        .into_iter()
        .map(|(h, opi, within, len)| (h, op_data_start[opi] + within as u64, len))
        .collect();
    Plan::Recipe {
        bytes: r.encode(),
        literals,
        hits,
        saved: (bytes.len() - rlen) as u64,
    }
}

/// Where body bytes go between `add` and `finish`.
enum BodySink {
    /// Raw framing: written straight through to the temp file (and the
    /// physical hash) as they arrive.
    Raw,
    /// Zstd framing: body bytes stream through a zstd encoder into a
    /// side temp file as they arrive, so peak memory is the encoder's
    /// window — not the pack's full logical body (`--full --framing
    /// zstd` over a huge store stays flat). The v2 byte format is
    /// unchanged: at `finish` the compressed frame is spliced into the
    /// pack behind its `ulen` prefix, feeding the running checksum.
    #[cfg(feature = "zstd")]
    Zstd {
        enc: zstd::stream::write::Encoder<'static, File>,
        /// The side temp file under the encoder (deleted after splice).
        path: PathBuf,
        /// Uncompressed body bytes fed so far (the `ulen` prefix).
        ulen: u64,
    },
}

pub struct PackWriter {
    dir: PathBuf,
    tmp_path: PathBuf,
    file: File,
    hasher: Sha256,
    entries: Vec<IdxEntry>,
    /// Depths of entries already added (feeds [`EntryMeta::infer`] for
    /// intra-pack parent chains).
    depths: HashMap<ObjectId, u32>,
    /// The framing sink (which framing was chosen lives in the pack
    /// header bytes already written).
    sink: BodySink,
    /// Physical bytes written so far (file offset).
    physical: u64,
    /// Logical offset: equal to `physical` for raw framing; tracks the
    /// *decoded* image for zstd framing (what index offsets refer to).
    logical: u64,
    /// Pack format version being written: [`VERSION`] normally,
    /// [`VERSION_CHUNKED`] when chunk dedup is on.
    version: u8,
    /// Chunk-dedup state; `None` for plain packs.
    dedup: Option<ChunkDedup>,
}

impl PackWriter {
    /// Start a new raw-framed pack in `pack_dir` (created if needed).
    /// The file stays a `tmp-*.packtmp` until [`PackWriter::finish`]
    /// renames it.
    pub fn create(pack_dir: &std::path::Path) -> Result<PackWriter> {
        Self::create_with(pack_dir, PackFraming::Raw)
    }

    /// Start a new pack with an explicit outer framing.
    /// [`PackFraming::Zstd`] needs the `zstd` feature.
    pub fn create_with(
        pack_dir: &std::path::Path,
        framing: PackFraming,
    ) -> Result<PackWriter> {
        Self::create_impl(pack_dir, framing, VERSION, None)
    }

    /// Start a chunk-dedup (pack v3) writer: objects whose
    /// content-defined chunks already occur earlier in this pack are
    /// stored as `MGCR` recipes. Reads stay bit-exact
    /// ([`PackFile::get`] reassembles transparently); the sidecar index
    /// becomes v4 when any recipe is actually written.
    pub fn create_chunked(
        pack_dir: &std::path::Path,
        framing: PackFraming,
    ) -> Result<PackWriter> {
        let dedup = ChunkDedup {
            cfg: ChunkConfig::default(),
            table: HashMap::new(),
            shared: 0,
            bytes_saved: 0,
            recipes: 0,
        };
        Self::create_impl(pack_dir, framing, VERSION_CHUNKED, Some(dedup))
    }

    fn create_impl(
        pack_dir: &std::path::Path,
        framing: PackFraming,
        version: u8,
        dedup: Option<ChunkDedup>,
    ) -> Result<PackWriter> {
        std::fs::create_dir_all(pack_dir)
            .with_context(|| format!("creating pack dir {}", pack_dir.display()))?;
        // Not `.pack`: a crash must not leave something PackedStore::open
        // would try to load as a sealed pack.
        let tmp_path = pack_dir.join(format!("tmp-{}.packtmp", std::process::id()));
        let file = File::create(&tmp_path)
            .with_context(|| format!("creating {}", tmp_path.display()))?;
        let sink = match framing {
            PackFraming::Raw => BodySink::Raw,
            #[cfg(feature = "zstd")]
            PackFraming::Zstd => {
                let zpath = pack_dir.join(format!("tmp-{}.ztmp", std::process::id()));
                let zfile = File::create(&zpath)
                    .with_context(|| format!("creating {}", zpath.display()))?;
                let enc = zstd::stream::write::Encoder::new(zfile, 6)
                    .context("starting zstd pack frame")?;
                BodySink::Zstd { enc, path: zpath, ulen: 0 }
            }
            #[cfg(not(feature = "zstd"))]
            PackFraming::Zstd => {
                let _ = std::fs::remove_file(&tmp_path);
                anyhow::bail!(
                    "zstd pack framing is not compiled into this build \
                     (rebuild with --features zstd)"
                );
            }
        };
        let mut w = PackWriter {
            dir: pack_dir.to_path_buf(),
            tmp_path,
            file,
            hasher: Sha256::new(),
            entries: Vec::new(),
            depths: HashMap::new(),
            sink,
            physical: 0,
            logical: 0,
            version,
            dedup,
        };
        w.write_physical(PACK_MAGIC)?;
        w.write_physical(&[version])?;
        w.write_physical(&[framing.code()])?;
        w.logical = header_len(version);
        Ok(w)
    }

    /// Write bytes to the physical file + running checksum (header,
    /// raw-framed body, trailer).
    fn write_physical(&mut self, bytes: &[u8]) -> Result<()> {
        self.file.write_all(bytes)?;
        self.hasher.update(bytes);
        self.physical += bytes.len() as u64;
        Ok(())
    }

    /// Write body bytes through the framing sink, advancing the logical
    /// offset.
    fn write_body(&mut self, bytes: &[u8]) -> Result<()> {
        match &mut self.sink {
            BodySink::Raw => {}
            #[cfg(feature = "zstd")]
            BodySink::Zstd { enc, ulen, .. } => {
                enc.write_all(bytes)?;
                *ulen += bytes.len() as u64;
                self.logical += bytes.len() as u64;
                return Ok(());
            }
        }
        self.write_physical(bytes)?;
        self.logical = self.physical;
        Ok(())
    }

    /// Append one object, deriving its index metadata from the object
    /// header (exact kind/parent; depth exact when the parent is in this
    /// pack, a lower bound otherwise). Ids must be unique within a pack
    /// (checked at `finish` when the index is built).
    pub fn add(&mut self, id: ObjectId, bytes: &[u8]) -> Result<()> {
        let meta = EntryMeta::infer(bytes, |p| self.depths.get(p).copied());
        self.add_with_meta(id, bytes, meta)
    }

    /// Append one object with caller-supplied index metadata (the
    /// repacker passes globally exact chain depths). Under a chunked
    /// writer the stored bytes may be an `MGCR` recipe; the index entry
    /// records which, and `len`/`offset` always describe the bytes as
    /// stored.
    pub fn add_with_meta(&mut self, id: ObjectId, bytes: &[u8], meta: EntryMeta) -> Result<()> {
        let plan = match &self.dedup {
            Some(d) => plan_entry(bytes, d),
            None => Plan::Passthrough,
        };
        match plan {
            Plan::Passthrough => {
                self.write_body(&(bytes.len() as u64).to_le_bytes())?;
                let offset = self.logical;
                self.write_body(bytes)?;
                self.push_entry(id, offset, bytes.len() as u64, meta, false);
            }
            Plan::Inline(chunks) => {
                self.write_body(&(bytes.len() as u64).to_le_bytes())?;
                let offset = self.logical;
                self.write_body(bytes)?;
                if let Some(d) = &mut self.dedup {
                    for c in &chunks {
                        d.table.entry(c.hash).or_insert((offset + c.start as u64, c.len));
                    }
                }
                self.push_entry(id, offset, bytes.len() as u64, meta, false);
            }
            Plan::Recipe { bytes: rbytes, literals, hits, saved } => {
                self.write_body(&(rbytes.len() as u64).to_le_bytes())?;
                let offset = self.logical;
                self.write_body(&rbytes)?;
                if let Some(d) = &mut self.dedup {
                    for (h, rel, len) in &literals {
                        d.table.entry(*h).or_insert((offset + rel, *len));
                    }
                    d.shared += hits;
                    d.bytes_saved += saved;
                    d.recipes += 1;
                }
                OBS_CHUNKS_SHARED.add(hits);
                OBS_BYTES_SAVED.add(saved);
                self.push_entry(id, offset, rbytes.len() as u64, meta, true);
            }
        }
        Ok(())
    }

    fn push_entry(&mut self, id: ObjectId, offset: u64, len: u64, meta: EntryMeta, recipe: bool) {
        self.depths.insert(id, meta.depth);
        self.entries.push(IdxEntry { id, offset, len, meta: Some(meta), recipe });
    }

    pub fn object_count(&self) -> usize {
        self.entries.len()
    }

    /// Chunk-dedup totals so far: (shared chunk copies, bytes saved vs.
    /// inline storage, recipe entries written). All zero for plain
    /// writers.
    pub fn dedup_stats(&self) -> (u64, u64, usize) {
        match &self.dedup {
            Some(d) => (d.shared, d.bytes_saved, d.recipes),
            None => (0, 0, 0),
        }
    }

    /// Seal the pack: flush the framed body (zstd), write the count
    /// trailer + checksum, rename to `pack-<sha256>.pack`, and write the
    /// sidecar v2 `.idx`.
    pub fn finish(mut self) -> Result<PackFile> {
        match std::mem::replace(&mut self.sink, BodySink::Raw) {
            BodySink::Raw => {}
            #[cfg(feature = "zstd")]
            BodySink::Zstd { enc, path, ulen } => {
                debug_assert_eq!(ulen, self.logical - header_len(self.version));
                drop(enc.finish().context("sealing zstd pack frame")?);
                self.write_physical(&ulen.to_le_bytes())?;
                // Splice the compressed frame through the running
                // checksum in bounded chunks.
                let mut src = File::open(&path)
                    .with_context(|| format!("reopening {}", path.display()))?;
                let mut buf = vec![0u8; 1 << 20];
                loop {
                    let n = src.read(&mut buf)?;
                    if n == 0 {
                        break;
                    }
                    self.write_physical(&buf[..n])?;
                }
                drop(src);
                let _ = std::fs::remove_file(&path);
            }
        }
        let count = self.entries.len() as u64;
        self.write_physical(&count.to_le_bytes())?;
        let PackWriter { dir, tmp_path, mut file, hasher, entries, .. } = self;
        let sha: [u8; 32] = hasher.finalize().into();
        file.write_all(&sha)?;
        file.sync_all()?;
        drop(file);

        let hex: String = sha.iter().map(|b| format!("{b:02x}")).collect();
        let pack_path = dir.join(format!("pack-{hex}.pack"));
        let index = PackIndex::from_entries(entries, sha)?;
        // Index first, then rename: the rename is the atomic commit point
        // (an orphaned .idx is ignored by the pack scan; a sealed pack
        // without its index would make the store unopenable).
        index.save(&PackFile::idx_path(&pack_path))?;
        std::fs::rename(&tmp_path, &pack_path)?;
        PackFile::open(&pack_path)
    }

    /// Drop the partial pack without sealing it.
    pub fn abort(self) -> Result<()> {
        match self.sink {
            BodySink::Raw => {}
            #[cfg(feature = "zstd")]
            BodySink::Zstd { enc, path, .. } => {
                // Drop the encoder unfinished and clear its side temp
                // file along with the pack's.
                drop(enc);
                if path.exists() {
                    std::fs::remove_file(&path)?;
                }
            }
        }
        drop(self.file);
        if self.tmp_path.exists() {
            std::fs::remove_file(&self.tmp_path)?;
        }
        Ok(())
    }
}
