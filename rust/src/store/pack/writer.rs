//! Streaming pack writer: objects are appended to a temp file (raw
//! framing) or a zstd stream (zstd framing) with a running SHA-256;
//! `finish` seals the trailer, renames the pack to its content hash, and
//! writes the sidecar v2 index (delta-parent/kind/depth metadata per
//! entry).

use std::collections::HashMap;
use std::fs::File;
#[cfg(feature = "zstd")]
use std::io::Read;
use std::io::Write;
use std::path::PathBuf;

use anyhow::{Context, Result};
use sha2::{Digest, Sha256};

use super::{
    header_len, EntryMeta, IdxEntry, PackFile, PackFraming, PackIndex, PACK_MAGIC, VERSION,
};
use crate::store::ObjectId;

/// Where body bytes go between `add` and `finish`.
enum BodySink {
    /// Raw framing: written straight through to the temp file (and the
    /// physical hash) as they arrive.
    Raw,
    /// Zstd framing: body bytes stream through a zstd encoder into a
    /// side temp file as they arrive, so peak memory is the encoder's
    /// window — not the pack's full logical body (`--full --framing
    /// zstd` over a huge store stays flat). The v2 byte format is
    /// unchanged: at `finish` the compressed frame is spliced into the
    /// pack behind its `ulen` prefix, feeding the running checksum.
    #[cfg(feature = "zstd")]
    Zstd {
        enc: zstd::stream::write::Encoder<'static, File>,
        /// The side temp file under the encoder (deleted after splice).
        path: PathBuf,
        /// Uncompressed body bytes fed so far (the `ulen` prefix).
        ulen: u64,
    },
}

pub struct PackWriter {
    dir: PathBuf,
    tmp_path: PathBuf,
    file: File,
    hasher: Sha256,
    entries: Vec<IdxEntry>,
    /// Depths of entries already added (feeds [`EntryMeta::infer`] for
    /// intra-pack parent chains).
    depths: HashMap<ObjectId, u32>,
    /// The framing sink (which framing was chosen lives in the pack
    /// header bytes already written).
    sink: BodySink,
    /// Physical bytes written so far (file offset).
    physical: u64,
    /// Logical offset: equal to `physical` for raw framing; tracks the
    /// *decoded* image for zstd framing (what index offsets refer to).
    logical: u64,
}

impl PackWriter {
    /// Start a new raw-framed pack in `pack_dir` (created if needed).
    /// The file stays a `tmp-*.packtmp` until [`PackWriter::finish`]
    /// renames it.
    pub fn create(pack_dir: &std::path::Path) -> Result<PackWriter> {
        Self::create_with(pack_dir, PackFraming::Raw)
    }

    /// Start a new pack with an explicit outer framing.
    /// [`PackFraming::Zstd`] needs the `zstd` feature.
    pub fn create_with(
        pack_dir: &std::path::Path,
        framing: PackFraming,
    ) -> Result<PackWriter> {
        std::fs::create_dir_all(pack_dir)
            .with_context(|| format!("creating pack dir {}", pack_dir.display()))?;
        // Not `.pack`: a crash must not leave something PackedStore::open
        // would try to load as a sealed pack.
        let tmp_path = pack_dir.join(format!("tmp-{}.packtmp", std::process::id()));
        let file = File::create(&tmp_path)
            .with_context(|| format!("creating {}", tmp_path.display()))?;
        let sink = match framing {
            PackFraming::Raw => BodySink::Raw,
            #[cfg(feature = "zstd")]
            PackFraming::Zstd => {
                let zpath = pack_dir.join(format!("tmp-{}.ztmp", std::process::id()));
                let zfile = File::create(&zpath)
                    .with_context(|| format!("creating {}", zpath.display()))?;
                let enc = zstd::stream::write::Encoder::new(zfile, 6)
                    .context("starting zstd pack frame")?;
                BodySink::Zstd { enc, path: zpath, ulen: 0 }
            }
            #[cfg(not(feature = "zstd"))]
            PackFraming::Zstd => {
                let _ = std::fs::remove_file(&tmp_path);
                anyhow::bail!(
                    "zstd pack framing is not compiled into this build \
                     (rebuild with --features zstd)"
                );
            }
        };
        let mut w = PackWriter {
            dir: pack_dir.to_path_buf(),
            tmp_path,
            file,
            hasher: Sha256::new(),
            entries: Vec::new(),
            depths: HashMap::new(),
            sink,
            physical: 0,
            logical: 0,
        };
        w.write_physical(PACK_MAGIC)?;
        w.write_physical(&[VERSION])?;
        w.write_physical(&[framing.code()])?;
        w.logical = header_len(VERSION);
        Ok(w)
    }

    /// Write bytes to the physical file + running checksum (header,
    /// raw-framed body, trailer).
    fn write_physical(&mut self, bytes: &[u8]) -> Result<()> {
        self.file.write_all(bytes)?;
        self.hasher.update(bytes);
        self.physical += bytes.len() as u64;
        Ok(())
    }

    /// Write body bytes through the framing sink, advancing the logical
    /// offset.
    fn write_body(&mut self, bytes: &[u8]) -> Result<()> {
        match &mut self.sink {
            BodySink::Raw => {}
            #[cfg(feature = "zstd")]
            BodySink::Zstd { enc, ulen, .. } => {
                enc.write_all(bytes)?;
                *ulen += bytes.len() as u64;
                self.logical += bytes.len() as u64;
                return Ok(());
            }
        }
        self.write_physical(bytes)?;
        self.logical = self.physical;
        Ok(())
    }

    /// Append one object, deriving its index metadata from the object
    /// header (exact kind/parent; depth exact when the parent is in this
    /// pack, a lower bound otherwise). Ids must be unique within a pack
    /// (checked at `finish` when the index is built).
    pub fn add(&mut self, id: ObjectId, bytes: &[u8]) -> Result<()> {
        let meta = EntryMeta::infer(bytes, |p| self.depths.get(p).copied());
        self.add_with_meta(id, bytes, meta)
    }

    /// Append one object with caller-supplied index metadata (the
    /// repacker passes globally exact chain depths).
    pub fn add_with_meta(&mut self, id: ObjectId, bytes: &[u8], meta: EntryMeta) -> Result<()> {
        self.write_body(&(bytes.len() as u64).to_le_bytes())?;
        let offset = self.logical;
        self.write_body(bytes)?;
        self.depths.insert(id, meta.depth);
        self.entries.push(IdxEntry {
            id,
            offset,
            len: bytes.len() as u64,
            meta: Some(meta),
        });
        Ok(())
    }

    pub fn object_count(&self) -> usize {
        self.entries.len()
    }

    /// Seal the pack: flush the framed body (zstd), write the count
    /// trailer + checksum, rename to `pack-<sha256>.pack`, and write the
    /// sidecar v2 `.idx`.
    pub fn finish(mut self) -> Result<PackFile> {
        match std::mem::replace(&mut self.sink, BodySink::Raw) {
            BodySink::Raw => {}
            #[cfg(feature = "zstd")]
            BodySink::Zstd { enc, path, ulen } => {
                debug_assert_eq!(ulen, self.logical - header_len(VERSION));
                drop(enc.finish().context("sealing zstd pack frame")?);
                self.write_physical(&ulen.to_le_bytes())?;
                // Splice the compressed frame through the running
                // checksum in bounded chunks.
                let mut src = File::open(&path)
                    .with_context(|| format!("reopening {}", path.display()))?;
                let mut buf = vec![0u8; 1 << 20];
                loop {
                    let n = src.read(&mut buf)?;
                    if n == 0 {
                        break;
                    }
                    self.write_physical(&buf[..n])?;
                }
                drop(src);
                let _ = std::fs::remove_file(&path);
            }
        }
        let count = self.entries.len() as u64;
        self.write_physical(&count.to_le_bytes())?;
        let PackWriter { dir, tmp_path, mut file, hasher, entries, .. } = self;
        let sha: [u8; 32] = hasher.finalize().into();
        file.write_all(&sha)?;
        file.sync_all()?;
        drop(file);

        let hex: String = sha.iter().map(|b| format!("{b:02x}")).collect();
        let pack_path = dir.join(format!("pack-{hex}.pack"));
        let index = PackIndex::from_entries(entries, sha)?;
        // Index first, then rename: the rename is the atomic commit point
        // (an orphaned .idx is ignored by the pack scan; a sealed pack
        // without its index would make the store unopenable).
        index.save(&PackFile::idx_path(&pack_path))?;
        std::fs::rename(&tmp_path, &pack_path)?;
        PackFile::open(&pack_path)
    }

    /// Drop the partial pack without sealing it.
    pub fn abort(self) -> Result<()> {
        match self.sink {
            BodySink::Raw => {}
            #[cfg(feature = "zstd")]
            BodySink::Zstd { enc, path, .. } => {
                // Drop the encoder unfinished and clear its side temp
                // file along with the pack's.
                drop(enc);
                if path.exists() {
                    std::fs::remove_file(&path)?;
                }
            }
        }
        drop(self.file);
        if self.tmp_path.exists() {
            std::fs::remove_file(&self.tmp_path)?;
        }
        Ok(())
    }
}
