//! Repacking: migrate live objects into packs, re-basing over-deep delta
//! chains on the way.
//!
//! Liveness is defined by the lineage graph: the caller passes every
//! object id referenced by a stored model (see
//! `LineageGraph::object_roots`), and the repacker walks delta-parent
//! references transitively, exactly like GC marking.
//!
//! ## Modes
//!
//! * [`RepackMode::Incremental`] (the CLI default) packs **only live
//!   loose objects** into one fresh pack and leaves every existing pack
//!   untouched — re-encoding and pack-write cost is proportional to what
//!   changed since the last repack, not to store size (the liveness mark
//!   still reads each live object once to follow parent pointers; making
//!   that walk metadata-only is a roadmap item). New deltas re-base against
//!   already-packed ancestors exactly as in a full repack (cross-pack
//!   parent references are first-class), so the chain-depth cap holds
//!   for everything newly packed; chains living entirely inside old
//!   packs keep their depth until the next full repack. Repeated
//!   incremental repacks grow a *generation* of packs, oldest first.
//! * [`RepackMode::Full`] rewrites the whole store into a single pack
//!   (the original behaviour): every live chain is depth-capped, dead
//!   packed objects are carried or pruned, and old packs are deleted.
//!
//! ## Generation-aware escalation
//!
//! Incremental repacks have two blind spots: pack generations accumulate
//! (every read consults every index) and garbage sealed inside packs is
//! never reclaimed. [`RepackConfig::max_generations`] and
//! [`RepackConfig::max_dead_ratio`] bound both — when either threshold
//! is exceeded an incremental run auto-promotes itself to a full
//! rewrite, and [`RepackReport::escalated`] records why. The dead-byte
//! trigger additionally requires [`RepackConfig::prune`] — a full
//! rewrite that carried its garbage would re-escalate forever. The CLI
//! enables escalation by default (`mgit repack --auto-full-gens 16
//! --auto-full-dead 0.5`); at the library level both default to `None`.
//!
//! ## Chain re-basing
//!
//! Reconstruction cost grows linearly with chain depth (the chain-depth
//! guidance in SNIPPETS.md: depth ≲10 reconstructs fast, deeper chains
//! pay diminishing returns), so chains longer than
//! [`RepackConfig::max_chain_depth`] are shortened. Object ids name
//! *logical tensor content*, so re-encoding must be value-exact or the
//! id would no longer match its content. Two-tier policy, applied
//! parents-first:
//!
//! 1. **Re-base onto a nearer ancestor**: re-quantize the object's
//!    resolved values against the nearest ancestor whose (new) depth
//!    still admits a child. Accepted only if reconstruction is
//!    *bit-exact* and the encoding still beats raw storage.
//! 2. **New base**: otherwise the object is stored raw (its payload *is*
//!    its logical content, so the id is preserved by construction) —
//!    MediaGit's "gc creates new bases" policy.
//!
//! Either way every previously readable id stays readable and resolves
//! to identical bytes, and no live chain exceeds `max_chain_depth`.
//!
//! ## Similarity-driven base selection (`repack --similarity`)
//!
//! With [`RepackConfig::similarity`] set, the delta pass stops trusting
//! lineage alone. Every processed tensor contributes a min-hash sketch
//! ([`crate::delta::similarity`]) over its content-defined chunks, and
//! each delta is scored against the lineage parent, the depth-repair
//! ancestor, and the best sketch-similar non-parents. The smallest
//! bit-exact encoding wins; if none saves at least
//! [`RepackConfig::min_savings`] of the raw f32 bytes, the object is
//! stored raw instead (no delta at all). Candidates are restricted to
//! objects processed *earlier* in the depth-sorted order, so the
//! re-based parent graph is acyclic by construction. Pairing the pass
//! with [`RepackConfig::chunk_dedup`] writes the pack in chunked v3
//! format so byte ranges shared across unrelated objects are stored
//! once. The full model lives in `docs/COMPRESSION.md`.
//!
//! After the new pack is sealed, old packs are deleted (full mode only),
//! loose copies of packed objects are removed (the loose directory
//! becomes a pure write-staging area), and with [`RepackConfig::prune`]
//! unreachable objects are dropped entirely; without it, dead packed
//! objects are carried over verbatim (full mode) and dead loose objects
//! are left in place.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;

use anyhow::{anyhow, bail, Context, Result};

use super::{EntryMeta, PackFile, PackFraming, PackWriter};
use crate::delta::chunk::{chunk_bytes, ChunkConfig};
use crate::delta::similarity::Sketch;
use crate::delta::{self, Codec, DeltaKernel};
use crate::store::format::{payload_decodes, ObjectKind, TensorObject};
use crate::store::{ObjectId, ObjectStore, Store};
use crate::tensor::f32_to_bytes;

/// Deltas re-based onto a similar non-parent during repack
/// (`delta.base_rewrites`).
static OBS_BASE_REWRITES: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("delta.base_rewrites");

/// How many sketch-ranked candidates get a bit-exact re-encode trial
/// per object. Trials are the expensive step (full resolve + quantize +
/// compress), so only the best-scoring few are attempted.
const MAX_BASE_TRIALS: usize = 4;

/// Which candidate won similarity-driven base selection for one delta.
enum BasePick {
    /// The lineage parent's existing encoding (kept verbatim).
    Parent,
    /// The depth-repair ancestor (counts as `rebased_delta`).
    Ancestor,
    /// A sketch-ranked non-parent (counts as `base_rewrites`).
    Similar,
}

/// Whether a repack rewrites everything or only packs new loose objects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RepackMode {
    /// Pack only live loose objects into one fresh pack; existing packs
    /// are left untouched (cost ∝ new data).
    Incremental,
    /// Rewrite the whole store into a single pack (cost ∝ store size).
    Full,
}

/// Tuning for [`repack()`].
#[derive(Debug, Clone, Copy)]
pub struct RepackConfig {
    /// Longest allowed delta chain after repacking (≥ 1).
    pub max_chain_depth: usize,
    /// Drop unreachable objects instead of carrying them over. In
    /// incremental mode only unreachable *loose* objects can be dropped;
    /// packed garbage needs a full repack to reclaim.
    pub prune: bool,
    /// Incremental (pack only new loose objects) or full rewrite.
    pub mode: RepackMode,
    /// Generation-aware escalation: an incremental repack auto-promotes
    /// to a full rewrite once more than this many pack generations have
    /// accumulated (each incremental run appends one). `None` disables.
    pub max_generations: Option<usize>,
    /// Escalation on garbage: auto-promote when the fraction of sealed
    /// pack bytes holding *unreachable* objects exceeds this ratio
    /// (incremental repacks can never reclaim packed garbage). Only
    /// consulted together with [`RepackConfig::prune`] — a full rewrite
    /// that carries its garbage would leave the ratio unchanged and
    /// re-escalate forever. `None` disables.
    pub max_dead_ratio: Option<f64>,
    /// Outer framing of the pack this run writes ([`PackFraming::Raw`]
    /// by default; `Zstd` needs the feature-gated dependency).
    pub framing: PackFraming,
    /// Force the mark phase back onto the legacy decode-every-object
    /// walk instead of the v2 index-metadata walk. The two are
    /// output-equivalent (tested byte-for-byte); this knob exists as the
    /// validation oracle and for debugging suspected index metadata
    /// corruption.
    pub decode_mark: bool,
    /// Keep loose copies of objects that are now packed instead of
    /// demoting (deleting) them. A live writable server repacks with
    /// this on: readers still holding a pre-repack store snapshot have
    /// never opened the new pack, so the loose staging copies are their
    /// only path to the data. A later offline `mgit repack` (default:
    /// off) demotes them.
    pub keep_loose: bool,
    /// Similarity-driven delta base selection. `Some(t)` turns the
    /// repack's delta pass into a candidate scorer: for each delta it
    /// considers the lineage parent, the depth-repair ancestor, and any
    /// already-processed object whose min-hash sketch scores ≥ `t`
    /// (0..=1), keeping whichever bit-exact encoding is smallest — or no
    /// delta at all when none beats [`RepackConfig::min_savings`].
    /// `None` (default) keeps the classic lineage-only pass byte-exact.
    pub similarity: Option<f64>,
    /// Minimum fractional saving a delta must achieve over raw f32 bytes
    /// to be kept (0..1). A delta whose encoding is larger than
    /// `(1 - min_savings) × raw` is dropped and the object stored raw —
    /// mediagit's "similar enough *and* saves enough" rule. Only
    /// consulted when [`RepackConfig::similarity`] is on.
    pub min_savings: f64,
    /// Write the new pack in chunked v3 format: content-defined chunks
    /// shared with earlier objects in the same pack are stored once and
    /// replayed through `MGCR` recipes. Reads stay bit-exact; old packs
    /// are untouched.
    pub chunk_dedup: bool,
}

impl Default for RepackConfig {
    fn default() -> Self {
        // SNIPPETS.md chain-depth guidance: 1–10 reconstructs fast.
        // Escalation is opt-in at the library level (the CLI enables it
        // with its own defaults).
        RepackConfig {
            max_chain_depth: 8,
            prune: false,
            mode: RepackMode::Incremental,
            max_generations: None,
            max_dead_ratio: None,
            framing: PackFraming::Raw,
            decode_mark: false,
            keep_loose: false,
            similarity: None,
            min_savings: 0.1,
            chunk_dedup: false,
        }
    }
}

/// What one [`repack()`] run did (counts, byte deltas, depth changes).
#[derive(Debug, Default)]
pub struct RepackReport {
    /// Live objects written into the new pack.
    pub packed: usize,
    /// Live objects left in place inside existing packs (incremental).
    pub retained_packed: usize,
    /// Unreachable packed objects carried over (full mode, prune off).
    pub carried_dead: usize,
    /// Chains re-based onto a nearer ancestor (still delta-encoded).
    pub rebased_delta: usize,
    /// Chains cut by promoting an object to a new raw base.
    pub new_bases: usize,
    /// Loose files deleted because the object is now packed.
    pub loose_demoted: usize,
    /// Unreachable loose objects deleted (prune on).
    pub pruned_loose: usize,
    /// Store payload bytes before the repack.
    pub bytes_before: u64,
    /// Store payload bytes after the repack.
    pub bytes_after: u64,
    /// Longest live chain before the repack.
    pub max_depth_before: usize,
    /// Longest live chain after the repack (see [`RepackMode`] for what
    /// incremental mode guarantees).
    pub max_depth_after: usize,
    /// Packs loaded before / after the repack.
    pub packs_before: usize,
    /// See [`RepackReport::packs_before`].
    pub packs_after: usize,
    /// Path of the freshly written pack, if any objects needed packing.
    pub pack_path: Option<PathBuf>,
    /// Why an incremental run was auto-promoted to a full rewrite
    /// (generation or dead-byte threshold), if it was.
    pub escalated: Option<String>,
    /// Fraction of sealed pack bytes that were unreachable at mark time
    /// (the dead-byte ratio the escalation decision saw).
    pub dead_ratio: f64,
    /// Full payload decodes performed by the mark phase. Zero when every
    /// live object is covered by v2 index metadata or loose header
    /// parses; nonzero only under [`RepackConfig::decode_mark`].
    pub mark_payload_decodes: u64,
    /// Live objects whose chain metadata needed an object-byte read
    /// during marking (loose staging copies and v1-pack copies); objects
    /// answered from v2 index metadata are not counted.
    pub mark_meta_fallback: usize,
    /// Outer framing of the pack this run wrote.
    pub framing: PackFraming,
    /// Deltas re-based onto a sketch-similar *non-parent* (similarity
    /// pass only; re-bases onto a lineage ancestor stay in
    /// [`RepackReport::rebased_delta`]).
    pub base_rewrites: usize,
    /// Deltas dropped because no candidate base met
    /// [`RepackConfig::min_savings`]; the object was stored raw even
    /// though its chain depth was fine.
    pub delta_skipped: usize,
    /// Content-defined chunks served from earlier pack bytes instead of
    /// being stored again ([`RepackConfig::chunk_dedup`]).
    pub chunks_shared: u64,
    /// Bytes saved by chunk dedup (shared bytes minus recipe overhead).
    pub chunk_bytes_saved: u64,
    /// Objects stored as `MGCR` recipes in the new pack.
    pub recipes: usize,
}

/// Chain depth of every object in the store (0 = raw/opaque base).
/// Dangling parents are treated as bases so depths stay defined; `fsck`
/// reports the dangling reference itself.
///
/// Chain discovery goes through [`Store::object_meta`]: objects covered
/// by v2 pack-index metadata contribute their parent edge with zero
/// object reads; loose and v1-packed objects fall back to a header-only
/// parse (never a payload decode).
pub fn chain_depths(store: &Store) -> Result<HashMap<ObjectId, usize>> {
    let ids = store.list()?;
    let mut parent: HashMap<ObjectId, Option<ObjectId>> = HashMap::with_capacity(ids.len());
    for id in &ids {
        parent.insert(*id, store.object_meta(id)?.parent);
    }
    chain_depths_from_parents(&parent)
}

/// [`chain_depths`] from a prebuilt parent map (`None` = raw/opaque
/// base), for callers that already decoded every object once.
pub fn chain_depths_from_parents(
    parent: &HashMap<ObjectId, Option<ObjectId>>,
) -> Result<HashMap<ObjectId, usize>> {
    let mut depth: HashMap<ObjectId, usize> = HashMap::with_capacity(parent.len());
    for &start in parent.keys() {
        if depth.contains_key(&start) {
            continue;
        }
        let mut chain: Vec<ObjectId> = Vec::new();
        let mut cur = start;
        let base_depth = loop {
            if let Some(&d) = depth.get(&cur) {
                break d;
            }
            match parent.get(&cur) {
                Some(Some(p)) => {
                    chain.push(cur);
                    if chain.len() > parent.len() {
                        bail!("delta chain cycle detected at {}", cur.short());
                    }
                    let p = *p;
                    if !parent.contains_key(&p) {
                        break 0; // dangling parent: treat as a base
                    }
                    cur = p;
                }
                Some(None) => {
                    depth.insert(cur, 0);
                    break 0;
                }
                None => break 0,
            }
        };
        let mut d = base_depth;
        for &c in chain.iter().rev() {
            d += 1;
            depth.insert(c, d);
        }
    }
    Ok(depth)
}

/// Repack `store` (must be pack-capable): walk live objects from
/// `roots`, re-base over-deep chains, and emit one compacted pack —
/// containing only the new loose objects in incremental mode, or the
/// whole live set in full mode. See the module docs for the full policy.
pub fn repack(
    store: &mut Store,
    roots: &[ObjectId],
    cfg: &RepackConfig,
    kernel: &dyn DeltaKernel,
) -> Result<RepackReport> {
    if cfg.max_chain_depth == 0 {
        bail!("max_chain_depth must be >= 1");
    }
    if let Some(t) = cfg.similarity {
        if !(0.0..=1.0).contains(&t) {
            bail!("similarity threshold must be within 0..=1, got {t}");
        }
    }
    if !(0.0..1.0).contains(&cfg.min_savings) {
        bail!("min_savings must be within 0..1, got {}", cfg.min_savings);
    }
    let packed = store
        .as_packed()
        .ok_or_else(|| anyhow!("repack needs a pack-capable store (Store::open_packed)"))?;
    let pack_dir = packed.pack_dir();
    let old_pack_paths: Vec<PathBuf> = packed.packs().iter().map(|p| p.path.clone()).collect();
    // Ids already sealed inside a pack: in incremental mode these are
    // retained verbatim (their packs are never rewritten).
    let in_pack: HashSet<ObjectId> = packed
        .packs()
        .iter()
        .flat_map(|p| p.index.ids().collect::<Vec<_>>())
        .collect();

    let mut report = RepackReport {
        bytes_before: store.stored_bytes()?,
        packs_before: old_pack_paths.len(),
        ..Default::default()
    };

    // ------------------------------------------------------------------
    // 1. Mark live objects (delta parents are strong, transitive refs)
    //    and record each live object's parent pointer.
    //
    //    The walk is metadata-only: objects sealed in v2 packs
    //    contribute their parent edge straight from the index (no object
    //    read at all); loose staging copies and v1-pack copies cost one
    //    byte read + header parse. Payload decodes happen only under the
    //    `decode_mark` oracle — the thread-local decode counter proves
    //    it (`RepackReport::mark_payload_decodes`).
    // ------------------------------------------------------------------
    let decodes_before_mark = payload_decodes();
    let mut live: HashSet<ObjectId> = HashSet::new();
    let mut parent_of: HashMap<ObjectId, Option<ObjectId>> = HashMap::new();
    let mut stack: Vec<ObjectId> = roots.to_vec();
    while let Some(id) = stack.pop() {
        if !live.insert(id) {
            continue;
        }
        let parent = if cfg.decode_mark {
            // Legacy path: full decode of every live object.
            let bytes = store
                .get(&id)
                .with_context(|| format!("repack: live object {} unreadable", id.short()))?;
            match TensorObject::decode(&bytes) {
                Ok(TensorObject::Delta { parent, .. }) => Some(parent),
                _ => None,
            }
        } else {
            let meta = store
                .object_meta(&id)
                .with_context(|| format!("repack: live object {} unreadable", id.short()))?;
            if !meta.from_index {
                // The answer needed a byte read + header parse (loose or
                // v1-packed copy).
                report.mark_meta_fallback += 1;
            }
            meta.parent
        };
        parent_of.insert(id, parent);
        if let Some(parent) = parent {
            if !live.contains(&parent) {
                stack.push(parent);
            }
        }
    }
    report.mark_payload_decodes = payload_decodes() - decodes_before_mark;
    report.framing = cfg.framing;

    // ------------------------------------------------------------------
    // 2. Original chain depths; process parents before children so a
    //    child always knows its (possibly re-based) parent's new depth.
    // ------------------------------------------------------------------
    let mut old_depth: HashMap<ObjectId, usize> = HashMap::with_capacity(live.len());
    for &id in &live {
        if old_depth.contains_key(&id) {
            continue;
        }
        let mut chain = Vec::new();
        let mut cur = id;
        let base = loop {
            if let Some(&d) = old_depth.get(&cur) {
                break d;
            }
            match parent_of.get(&cur).copied().flatten() {
                Some(p) => {
                    chain.push(cur);
                    if chain.len() > live.len() {
                        bail!("delta chain cycle detected at {}", cur.short());
                    }
                    cur = p;
                }
                None => {
                    old_depth.insert(cur, 0);
                    break 0;
                }
            }
        };
        let mut d = base;
        for &c in chain.iter().rev() {
            d += 1;
            old_depth.insert(c, d);
        }
    }
    // ------------------------------------------------------------------
    // 2a. Generation-aware escalation (incremental only): once the
    //     liveness mark is known, measure what an incremental run could
    //     never fix — accumulated pack generations and garbage sealed
    //     inside packs — and promote to a full rewrite past either
    //     configured threshold. The decision is recorded in the report.
    // ------------------------------------------------------------------
    let mut incremental = cfg.mode == RepackMode::Incremental;
    {
        let mut packed_bytes = 0u64;
        let mut dead_bytes = 0u64;
        for p in packed.packs() {
            for e in &p.index.entries {
                packed_bytes += e.len;
                if !live.contains(&e.id) {
                    dead_bytes += e.len;
                }
            }
        }
        report.dead_ratio = if packed_bytes > 0 {
            dead_bytes as f64 / packed_bytes as f64
        } else {
            0.0
        };
        if incremental {
            if let Some(max_gens) = cfg.max_generations {
                if max_gens > 0 && old_pack_paths.len() > max_gens {
                    report.escalated = Some(format!(
                        "{} pack generations > {max_gens}",
                        old_pack_paths.len()
                    ));
                }
            }
            if report.escalated.is_none() {
                // The ratio trigger only fires with prune: a full rewrite
                // that *carries* dead objects leaves the ratio unchanged
                // and would escalate every subsequent run forever without
                // reclaiming anything.
                if let (Some(max_ratio), true) = (cfg.max_dead_ratio, cfg.prune) {
                    if packed_bytes > 0 && report.dead_ratio > max_ratio {
                        report.escalated = Some(format!(
                            "dead-byte ratio {:.2} > {max_ratio:.2}",
                            report.dead_ratio
                        ));
                    }
                }
            }
            if report.escalated.is_some() {
                incremental = false;
            }
        }
    }
    report.max_depth_before = old_depth.values().copied().max().unwrap_or(0);

    let mut order: Vec<ObjectId> = live.iter().copied().collect();
    order.sort_by_key(|id| (old_depth[id], id.0));

    // ------------------------------------------------------------------
    // 3. Re-encode over-deep chains (id-preserving; see module docs).
    // ------------------------------------------------------------------
    let mut new_bytes: HashMap<ObjectId, Vec<u8>> = HashMap::with_capacity(order.len());
    let mut new_depth: HashMap<ObjectId, usize> = HashMap::with_capacity(order.len());
    // Index metadata for every freshly written object (exact depths:
    // this loop knows the global chain structure).
    let mut new_meta: HashMap<ObjectId, EntryMeta> = HashMap::with_capacity(order.len());
    let mut resolve_cache: HashMap<ObjectId, Vec<f32>> = HashMap::new();
    // Similarity pass state: every freshly processed tensor contributes
    // (id, numel, sketch) so *later* objects in the depth-sorted order
    // can consider it as a delta base. Earlier-only candidates make the
    // re-based graph acyclic by construction.
    let sketch_cfg = ChunkConfig::default();
    let mut cand_pool: Vec<(ObjectId, usize, Sketch)> = Vec::new();
    for &id in &order {
        if incremental && in_pack.contains(&id) {
            // Already sealed in a pack: retained as-is. Its depth still
            // feeds children's depth accounting (a new loose delta may
            // hang off it, or re-base onto one of its ancestors).
            new_depth.insert(id, old_depth[&id]);
            report.retained_packed += 1;
            continue;
        }
        let bytes = store.get(&id)?;
        let obj = match TensorObject::decode(&bytes) {
            Err(_) => {
                // Opaque (non-MGTF) blob: copy verbatim.
                new_depth.insert(id, 0);
                new_bytes.insert(id, bytes);
                new_meta.insert(
                    id,
                    EntryMeta { kind: ObjectKind::Opaque, parent: None, depth: 0, numel: Some(0) },
                );
                continue;
            }
            Ok(o) => o,
        };
        match obj {
            TensorObject::Raw { ref shape, ref payload, .. } => {
                let numel = Some(shape.iter().product::<usize>() as u64);
                if cfg.similarity.is_some() {
                    let sk = Sketch::of_chunks(&chunk_bytes(payload, &sketch_cfg));
                    cand_pool.push((id, payload.len() / 4, sk));
                }
                new_depth.insert(id, 0);
                new_bytes.insert(id, bytes);
                new_meta.insert(
                    id,
                    EntryMeta { kind: ObjectKind::Raw, parent: None, depth: 0, numel },
                );
            }
            TensorObject::Delta { dtype, shape, parent, eps, codec, grid, .. } => {
                let numel = Some(shape.iter().product::<usize>() as u64);
                let pd = *new_depth.get(&parent).ok_or_else(|| {
                    anyhow!(
                        "repack: parent {} of {} not processed — liveness walk inconsistent",
                        parent.short(),
                        id.short()
                    )
                })?;
                let depth_ok = pd + 1 <= cfg.max_chain_depth;
                if depth_ok && cfg.similarity.is_none() {
                    // Parent kept (or re-based value-exactly): the stored
                    // delta still reconstructs the identical content.
                    new_depth.insert(id, pd + 1);
                    new_bytes.insert(id, bytes);
                    new_meta.insert(
                        id,
                        EntryMeta {
                            kind: ObjectKind::Delta,
                            parent: Some(parent),
                            depth: (pd + 1) as u32,
                            numel,
                        },
                    );
                    continue;
                }
                let values = delta::resolve_tensor(store, id, kernel, &mut resolve_cache, 0)?;
                if let Some(threshold) = cfg.similarity {
                    // Similarity-driven base selection: score candidate
                    // bases, keep the smallest bit-exact encoding, or no
                    // delta at all when nothing meets `min_savings`.
                    let numel_n = values.len();
                    let raw_len = (numel_n * 4) as f64;
                    let payload = f32_to_bytes(&values);
                    let sketch = Sketch::of_chunks(&chunk_bytes(&payload, &sketch_cfg));
                    let budget_ok =
                        |encoded: usize| encoded as f64 <= (1.0 - cfg.min_savings) * raw_len;

                    // Baseline: what the classic pass would have done.
                    let mut best: Option<(Vec<u8>, ObjectId, usize, BasePick)> = None;
                    if depth_ok && budget_ok(bytes.len()) {
                        best = Some((bytes, parent, pd + 1, BasePick::Parent));
                    } else if !depth_ok {
                        let mut anc = parent;
                        loop {
                            if new_depth[&anc] + 1 <= cfg.max_chain_depth {
                                break;
                            }
                            match parent_of.get(&anc).copied().flatten() {
                                Some(p) => anc = p,
                                None => break, // raw base always admits a child
                            }
                        }
                        let anc_values =
                            delta::resolve_tensor(store, anc, kernel, &mut resolve_cache, 0)?;
                        if let Some(obj) = delta::reencode_exact(
                            &values,
                            &anc_values,
                            anc,
                            &shape,
                            eps,
                            Codec::from_code(codec)?,
                            grid,
                            kernel,
                        )? {
                            let enc = obj.encode();
                            if budget_ok(enc.len()) {
                                best = Some((enc, anc, new_depth[&anc] + 1, BasePick::Ancestor));
                            }
                        }
                    }
                    // Rank already-processed tensors by sketch score and
                    // give the best few a bit-exact re-encode trial.
                    let mut scored: Vec<(f64, ObjectId)> = cand_pool
                        .iter()
                        .filter(|(cid, n, _)| {
                            *cid != id
                                && *cid != parent
                                && *n == numel_n
                                && new_depth
                                    .get(cid)
                                    .is_some_and(|d| d + 1 <= cfg.max_chain_depth)
                        })
                        .map(|(cid, _, sk)| (sketch.similarity(sk), *cid))
                        .filter(|(score, _)| *score >= threshold)
                        .collect();
                    scored.sort_by(|a, b| b.0.total_cmp(&a.0));
                    for &(_, cid) in scored.iter().take(MAX_BASE_TRIALS) {
                        let cand_values =
                            delta::resolve_tensor(store, cid, kernel, &mut resolve_cache, 0)?;
                        if let Some(obj) = delta::reencode_exact(
                            &values,
                            &cand_values,
                            cid,
                            &shape,
                            eps,
                            Codec::from_code(codec)?,
                            grid,
                            kernel,
                        )? {
                            let enc = obj.encode();
                            let smaller =
                                best.as_ref().map_or(true, |(b, ..)| enc.len() < b.len());
                            if budget_ok(enc.len()) && smaller {
                                best = Some((enc, cid, new_depth[&cid] + 1, BasePick::Similar));
                            }
                        }
                    }
                    match best {
                        Some((enc, base, d, pick)) => {
                            match pick {
                                BasePick::Parent => {}
                                BasePick::Ancestor => report.rebased_delta += 1,
                                BasePick::Similar => {
                                    report.base_rewrites += 1;
                                    OBS_BASE_REWRITES.inc();
                                }
                            }
                            new_depth.insert(id, d);
                            new_bytes.insert(id, enc);
                            new_meta.insert(
                                id,
                                EntryMeta {
                                    kind: ObjectKind::Delta,
                                    parent: Some(base),
                                    depth: d as u32,
                                    numel,
                                },
                            );
                        }
                        None => {
                            // No base pays its way (mediagit's "similar
                            // enough AND saves enough" rule): store raw.
                            // The payload is the logical content, so the
                            // id is unchanged.
                            if depth_ok {
                                report.delta_skipped += 1;
                            } else {
                                report.new_bases += 1;
                            }
                            let raw = TensorObject::Raw { dtype, shape, payload };
                            new_depth.insert(id, 0);
                            new_bytes.insert(id, raw.encode());
                            new_meta.insert(
                                id,
                                EntryMeta {
                                    kind: ObjectKind::Raw,
                                    parent: None,
                                    depth: 0,
                                    numel,
                                },
                            );
                        }
                    }
                    cand_pool.push((id, numel_n, sketch));
                    continue;
                }
                // Chain too deep: re-base against the nearest ancestor
                // that can still take a child without busting the limit.
                let mut anc = parent;
                loop {
                    if new_depth[&anc] + 1 <= cfg.max_chain_depth {
                        break;
                    }
                    match parent_of.get(&anc).copied().flatten() {
                        Some(p) => anc = p,
                        None => break, // raw base (depth 0) — always admits a child
                    }
                }
                let anc_values =
                    delta::resolve_tensor(store, anc, kernel, &mut resolve_cache, 0)?;
                let rebased = delta::reencode_exact(
                    &values,
                    &anc_values,
                    anc,
                    &shape,
                    eps,
                    Codec::from_code(codec)?,
                    grid,
                    kernel,
                )?;
                match rebased {
                    Some(obj) => {
                        report.rebased_delta += 1;
                        new_depth.insert(id, new_depth[&anc] + 1);
                        new_bytes.insert(id, obj.encode());
                        new_meta.insert(
                            id,
                            EntryMeta {
                                kind: ObjectKind::Delta,
                                parent: Some(anc),
                                depth: new_depth[&id] as u32,
                                numel,
                            },
                        );
                    }
                    None => {
                        // Promote to a new raw base: the payload *is* the
                        // logical content, so the id is unchanged.
                        report.new_bases += 1;
                        let raw = TensorObject::Raw {
                            dtype,
                            shape,
                            payload: f32_to_bytes(&values),
                        };
                        new_depth.insert(id, 0);
                        new_bytes.insert(id, raw.encode());
                        new_meta.insert(
                            id,
                            EntryMeta { kind: ObjectKind::Raw, parent: None, depth: 0, numel },
                        );
                    }
                }
            }
        }
    }
    report.max_depth_after = new_depth.values().copied().max().unwrap_or(0);

    // ------------------------------------------------------------------
    // 4. Partition dead objects: packed ones are carried (full mode,
    //    prune off) or stay sealed in their packs (incremental);
    //    loose-only ones stay loose (or are pruned).
    // ------------------------------------------------------------------
    let mut dead_carry: Vec<ObjectId> = Vec::new();
    let mut dead_loose: Vec<ObjectId> = Vec::new();
    for id in store.list()? {
        if live.contains(&id) {
            continue;
        }
        if in_pack.contains(&id) {
            // Incremental mode never rewrites packs, so dead packed
            // objects simply stay where they are (a full repack with
            // --prune reclaims them).
            if !cfg.prune && !incremental {
                dead_carry.push(id);
            }
        } else {
            dead_loose.push(id);
        }
    }
    dead_carry.sort();
    dead_loose.sort();

    // ------------------------------------------------------------------
    // 5. Write the new pack (before touching anything existing). In
    //    incremental mode only freshly encoded (former loose) objects
    //    are in `new_bytes`; in full mode every live object is.
    // ------------------------------------------------------------------
    let mut writer = if cfg.chunk_dedup {
        PackWriter::create_chunked(&pack_dir, cfg.framing)?
    } else {
        PackWriter::create_with(&pack_dir, cfg.framing)?
    };
    for &id in &order {
        if let Some(bytes) = new_bytes.get(&id) {
            writer.add_with_meta(id, bytes, new_meta[&id])?;
            report.packed += 1;
        }
    }
    for &id in &dead_carry {
        // Dead objects carry best-effort inferred metadata (exact
        // kind/parent from the object header; depth is a lower bound
        // when the parent landed later in the sorted dead sweep).
        writer.add(id, &store.get(&id)?)?;
        report.carried_dead += 1;
    }
    let (chunks_shared, chunk_bytes_saved, recipes) = writer.dedup_stats();
    report.chunks_shared = chunks_shared;
    report.chunk_bytes_saved = chunk_bytes_saved;
    report.recipes = recipes;
    let new_pack: Option<PackFile> = if writer.object_count() > 0 {
        Some(writer.finish()?)
    } else {
        writer.abort()?;
        None
    };
    report.pack_path = new_pack.as_ref().map(|p| p.path.clone());

    // ------------------------------------------------------------------
    // 6. Swap packs in, demote loose copies, prune if asked.
    // ------------------------------------------------------------------
    let ps = store.as_packed_mut().unwrap();
    if incremental {
        // Append the fresh pack as the newest generation; existing packs
        // stay loaded and on disk.
        if let Some(p) = new_pack {
            if ps.packs().iter().all(|q| q.path != p.path) {
                ps.add_pack(p);
            }
        }
    } else {
        ps.replace_packs(new_pack.into_iter().collect());
        for p in &old_pack_paths {
            // Pack names are content-derived: an identical repack
            // re-creates the very same filename, which must not be
            // deleted as "old".
            if report.pack_path.as_ref() == Some(p) {
                continue;
            }
            let _ = std::fs::remove_file(PackFile::idx_path(p));
            let _ = std::fs::remove_file(p);
        }
    }
    // Every live object is now packed (either newly written or retained
    // in an old pack), so any loose copy is redundant staging — unless
    // the caller needs the loose copies kept for readers still on a
    // pre-repack store snapshot (live serve repack).
    if !cfg.keep_loose {
        for id in order.iter().chain(&dead_carry) {
            if ps.loose().remove(id)? {
                report.loose_demoted += 1;
            }
        }
    }
    if cfg.prune {
        for id in &dead_loose {
            if ps.loose().remove(id)? {
                report.pruned_loose += 1;
            }
        }
    }
    report.packs_after = ps.packs().len();
    report.bytes_after = store.stored_bytes()?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delta::NativeKernel;
    use crate::store::hash_bytes;

    fn tmp_store(tag: &str) -> (std::path::PathBuf, Store) {
        let dir =
            std::env::temp_dir().join(format!("mgit-repack-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Store::open_packed(&dir).unwrap();
        (dir, store)
    }

    /// Build a delta chain of `n` links over a raw base, storing real
    /// quantized deltas so chains resolve. Returns ids base-first.
    fn build_chain(store: &Store, n: usize, seed: u64) -> Vec<ObjectId> {
        use crate::store::hash_tensor;
        use crate::tensor::{i32_to_bytes, DType};
        use crate::util::rng::Rng;

        let mut rng = Rng::new(seed);
        let len = 256usize;
        let eps = 1e-4f32;
        let codec = Codec::Deflate;
        let base: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut ids = Vec::new();
        let base_payload = f32_to_bytes(&base);
        let base_id = hash_tensor(DType::F32, &[len], &base_payload);
        store
            .put(
                base_id,
                &TensorObject::Raw { dtype: DType::F32, shape: vec![len], payload: base_payload }
                    .encode(),
            )
            .unwrap();
        ids.push(base_id);
        let mut prev = base;
        let mut prev_id = base_id;
        for _ in 0..n {
            let child: Vec<f32> =
                prev.iter().map(|&p| p + rng.normal_f32(0.0, 3e-4)).collect();
            let q = NativeKernel.quantize(&prev, &child, eps).unwrap();
            let rec = NativeKernel.dequantize(&prev, &q, eps).unwrap();
            let payload = f32_to_bytes(&rec);
            let id = hash_tensor(DType::F32, &[len], &payload);
            let obj = TensorObject::Delta {
                dtype: DType::F32,
                shape: vec![len],
                parent: prev_id,
                eps,
                codec: codec.code(),
                n_quant: len,
                grid: false,
                payload: codec.compress(&i32_to_bytes(&q)).unwrap(),
            };
            store.put(id, &obj.encode()).unwrap();
            ids.push(id);
            prev = rec;
            prev_id = id;
        }
        ids
    }

    /// Append `n` delta links on top of `tip` (which must resolve),
    /// storing real quantized deltas loose. Returns new ids oldest-first.
    fn extend_chain(store: &Store, tip: ObjectId, n: usize, seed: u64) -> Vec<ObjectId> {
        use crate::store::hash_tensor;
        use crate::tensor::{i32_to_bytes, DType};
        use crate::util::rng::Rng;

        let mut rng = Rng::new(seed);
        let eps = 1e-4f32;
        let codec = Codec::Deflate;
        let mut cache = HashMap::new();
        let mut prev =
            delta::resolve_tensor(store, tip, &NativeKernel, &mut cache, 0).unwrap();
        let len = prev.len();
        let mut prev_id = tip;
        let mut ids = Vec::new();
        for _ in 0..n {
            let child: Vec<f32> =
                prev.iter().map(|&p| p + rng.normal_f32(0.0, 3e-4)).collect();
            let q = NativeKernel.quantize(&prev, &child, eps).unwrap();
            let rec = NativeKernel.dequantize(&prev, &q, eps).unwrap();
            let payload = f32_to_bytes(&rec);
            let id = hash_tensor(DType::F32, &[len], &payload);
            let obj = TensorObject::Delta {
                dtype: DType::F32,
                shape: vec![len],
                parent: prev_id,
                eps,
                codec: codec.code(),
                n_quant: len,
                grid: false,
                payload: codec.compress(&i32_to_bytes(&q)).unwrap(),
            };
            store.put(id, &obj.encode()).unwrap();
            ids.push(id);
            prev = rec;
            prev_id = id;
        }
        ids
    }

    fn resolve_all(store: &Store, ids: &[ObjectId]) -> Vec<Vec<f32>> {
        let mut cache = HashMap::new();
        ids.iter()
            .map(|id| {
                delta::resolve_tensor(store, *id, &NativeKernel, &mut cache, 0).unwrap()
            })
            .collect()
    }

    #[test]
    fn repack_preserves_content_and_caps_depth() {
        let (dir, mut store) = tmp_store("cap");
        let ids = build_chain(&store, 12, 1);
        let junk = store.put_blob(b"unreachable-junk").unwrap();
        let before = resolve_all(&store, &ids);

        let cfg = RepackConfig {
            max_chain_depth: 4,
            prune: false,
            mode: RepackMode::Full,
            ..RepackConfig::default()
        };
        let roots = vec![*ids.last().unwrap()];
        let report = repack(&mut store, &roots, &cfg, &NativeKernel).unwrap();
        assert_eq!(report.packed, ids.len());
        assert!(report.max_depth_before > cfg.max_chain_depth);
        assert!(report.max_depth_after <= cfg.max_chain_depth);
        assert!(report.rebased_delta + report.new_bases > 0);
        assert!(report.pack_path.is_some());

        // Every id still readable with identical resolved content.
        let after = resolve_all(&store, &ids);
        for (b, a) in before.iter().zip(&after) {
            assert_eq!(b.len(), a.len());
            for (x, y) in b.iter().zip(a) {
                assert_eq!(x.to_bits(), y.to_bits(), "content changed by repack");
            }
        }
        // Depths really are capped on disk, not just in the report.
        let depths = chain_depths(&store).unwrap();
        for id in &ids {
            assert!(depths[id] <= cfg.max_chain_depth);
        }
        // Loose dir demoted; junk survived (no prune).
        assert!(store.has(&junk));
        let ps = store.as_packed().unwrap();
        let (loose, packed) = ps.counts().unwrap();
        assert_eq!(packed, ids.len());
        assert_eq!(loose, 1, "only the junk blob stays loose");
        ps.packs()[0].verify().unwrap();

        // Re-open from disk: packs load from their indexes.
        let store2 = Store::open_packed(&dir).unwrap();
        let again = resolve_all(&store2, &ids);
        for (b, a) in before.iter().zip(&again) {
            for (x, y) in b.iter().zip(a) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn repack_prune_drops_unreachable() {
        let (dir, mut store) = tmp_store("prune");
        let ids = build_chain(&store, 3, 2);
        let junk = store.put_blob(b"dead-blob").unwrap();
        let cfg = RepackConfig {
            max_chain_depth: 8,
            prune: true,
            mode: RepackMode::Full,
            ..RepackConfig::default()
        };
        let roots = vec![*ids.last().unwrap()];
        let report = repack(&mut store, &roots, &cfg, &NativeKernel).unwrap();
        assert_eq!(report.pruned_loose, 1);
        assert!(!store.has(&junk));
        assert!(store.has(ids.last().unwrap()));
        assert!(report.bytes_after <= report.bytes_before);

        // A second repack with everything already packed produces the
        // same content-derived pack name and must NOT delete it as an
        // "old" pack — everything stays readable from disk.
        let report2 = repack(&mut store, &roots, &cfg, &NativeKernel).unwrap();
        assert_eq!(report2.packed, ids.len());
        assert_eq!(report2.carried_dead, 0);
        let store2 = Store::open_packed(&dir).unwrap();
        for id in &ids {
            assert!(store2.has(id), "object lost by idempotent repack");
            store2.get(id).unwrap();
        }
        store2.as_packed().unwrap().packs()[0].verify().unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn repack_without_prune_carries_dead_packed_objects() {
        let (dir, mut store) = tmp_store("carry");
        let ids = build_chain(&store, 2, 3);
        let cfg = RepackConfig {
            max_chain_depth: 8,
            prune: false,
            mode: RepackMode::Full,
            ..RepackConfig::default()
        };
        // First repack with the tip as root packs the whole chain.
        let tip = *ids.last().unwrap();
        repack(&mut store, &[tip], &cfg, &NativeKernel).unwrap();
        // Now repack rooted at the *base* only: the two deltas are dead
        // but packed, so they are carried over and stay readable.
        let report = repack(&mut store, &[ids[0]], &cfg, &NativeKernel).unwrap();
        assert_eq!(report.packed, 1);
        assert_eq!(report.carried_dead, 2);
        assert!(store.has(&tip));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn incremental_packs_only_new_loose_objects() {
        let (dir, mut store) = tmp_store("incr");
        let ids = build_chain(&store, 4, 7);
        let tip = *ids.last().unwrap();
        let full = RepackConfig {
            max_chain_depth: 8,
            prune: false,
            mode: RepackMode::Full,
            ..RepackConfig::default()
        };
        let r1 = repack(&mut store, &[tip], &full, &NativeKernel).unwrap();
        let first_pack = r1.pack_path.clone().unwrap();

        // Stage new work: two more chain links plus an unreachable blob.
        let ext = extend_chain(&store, tip, 2, 99);
        let junk = store.put_blob(b"stays-loose").unwrap();
        let all: Vec<ObjectId> = ids.iter().chain(&ext).copied().collect();
        let want = resolve_all(&store, &all);

        let inc = RepackConfig {
            max_chain_depth: 8,
            prune: false,
            mode: RepackMode::Incremental,
            ..RepackConfig::default()
        };
        let roots = vec![*ext.last().unwrap()];
        let r2 = repack(&mut store, &roots, &inc, &NativeKernel).unwrap();
        assert_eq!(r2.packed, ext.len(), "only new loose objects get packed");
        assert_eq!(r2.retained_packed, ids.len());
        assert_eq!(r2.carried_dead, 0);
        assert_eq!((r2.packs_before, r2.packs_after), (1, 2));
        assert!(first_pack.exists(), "incremental repack must keep old packs");
        assert_ne!(r2.pack_path.as_ref(), Some(&first_pack));
        assert!(store.has(&junk), "dead loose object survives without prune");
        let ps = store.as_packed().unwrap();
        let (loose, packed) = ps.counts().unwrap();
        assert_eq!(loose, 1, "only the junk blob stays loose");
        assert_eq!(packed, all.len());
        for p in ps.packs() {
            p.verify().unwrap();
        }

        // Bit-exact content through a fresh store handle.
        let store2 = Store::open_packed(&dir).unwrap();
        let got = resolve_all(&store2, &all);
        for (b, a) in want.iter().zip(&got) {
            for (x, y) in b.iter().zip(a) {
                assert_eq!(x.to_bits(), y.to_bits(), "content changed by repack");
            }
        }

        // A second incremental run with nothing staged is a no-op.
        let r3 = repack(&mut store, &roots, &inc, &NativeKernel).unwrap();
        assert_eq!(r3.packed, 0);
        assert!(r3.pack_path.is_none());
        assert_eq!(r3.packs_after, 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn incremental_rebases_against_packed_ancestors() {
        let (dir, mut store) = tmp_store("incr-rebase");
        let ids = build_chain(&store, 6, 11);
        let tip = *ids.last().unwrap();
        let full = RepackConfig {
            max_chain_depth: 8,
            prune: false,
            mode: RepackMode::Full,
            ..RepackConfig::default()
        };
        repack(&mut store, &[tip], &full, &NativeKernel).unwrap();

        // Extend loose past the cap: tips would reach depth 11.
        let ext = extend_chain(&store, tip, 5, 22);
        let all: Vec<ObjectId> = ids.iter().chain(&ext).copied().collect();
        let want = resolve_all(&store, &all);

        let inc = RepackConfig {
            max_chain_depth: 8,
            prune: false,
            mode: RepackMode::Incremental,
            ..RepackConfig::default()
        };
        let report =
            repack(&mut store, &[*ext.last().unwrap()], &inc, &NativeKernel).unwrap();
        assert_eq!(report.packed, ext.len());
        assert!(
            report.rebased_delta + report.new_bases > 0,
            "the over-deep extension must be re-based: {report:?}"
        );
        assert!(report.max_depth_after <= inc.max_chain_depth);
        let depths = chain_depths(&store).unwrap();
        for id in &all {
            assert!(depths[id] <= inc.max_chain_depth);
        }
        let got = resolve_all(&store, &all);
        for (b, a) in want.iter().zip(&got) {
            for (x, y) in b.iter().zip(a) {
                assert_eq!(x.to_bits(), y.to_bits(), "content changed by rebase");
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Tentpole: over a multi-generation v2 store with every live object
    /// sealed in packs, the incremental mark phase must not decode a
    /// single payload — it walks pure index metadata.
    #[test]
    fn incremental_mark_is_decode_free_on_v2_packs() {
        let (dir, mut store) = tmp_store("meta-mark");
        let ids = build_chain(&store, 5, 13);
        let mut tip = *ids.last().unwrap();
        let inc = RepackConfig {
            max_chain_depth: 8,
            mode: RepackMode::Incremental,
            ..RepackConfig::default()
        };
        // Two generations of v2 packs.
        repack(&mut store, &[tip], &inc, &NativeKernel).unwrap();
        tip = *extend_chain(&store, tip, 2, 14).last().unwrap();
        repack(&mut store, &[tip], &inc, &NativeKernel).unwrap();
        assert_eq!(store.as_packed().unwrap().packs().len(), 2);

        // Third run with nothing staged: all live objects are packed
        // with v2 metadata, so the mark phase is pure index walking.
        let r = repack(&mut store, &[tip], &inc, &NativeKernel).unwrap();
        assert_eq!(r.packed, 0);
        assert_eq!(
            r.mark_payload_decodes, 0,
            "metadata mark must not decode payloads"
        );
        assert_eq!(
            r.mark_meta_fallback, 0,
            "fully v2-packed store must not need byte reads during mark"
        );

        // The decode_mark oracle really does decode (counter sanity).
        let oracle = RepackConfig { decode_mark: true, ..inc };
        let r = repack(&mut store, &[tip], &oracle, &NativeKernel).unwrap();
        assert!(r.mark_payload_decodes > 0, "oracle path must count decodes");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// The metadata mark and the legacy decode mark must produce
    /// byte-identical packs and indexes (same liveness, same order, same
    /// re-encodings, same persisted metadata).
    #[test]
    fn metadata_mark_matches_decode_mark_byte_identical() {
        let run = |tag: &str, decode_mark: bool| -> (Vec<u8>, Vec<u8>, u64) {
            let (dir, mut store) = tmp_store(tag);
            let ids = build_chain(&store, 6, 77);
            let tip = *ids.last().unwrap();
            let full = RepackConfig {
                max_chain_depth: 8,
                mode: RepackMode::Full,
                ..RepackConfig::default()
            };
            repack(&mut store, &[tip], &full, &NativeKernel).unwrap();
            let ext = extend_chain(&store, tip, 4, 88);
            let cfg = RepackConfig {
                max_chain_depth: 8,
                mode: RepackMode::Incremental,
                decode_mark,
                ..RepackConfig::default()
            };
            let r =
                repack(&mut store, &[*ext.last().unwrap()], &cfg, &NativeKernel).unwrap();
            let pack_path = r.pack_path.expect("loose extension must produce a pack");
            let pack = std::fs::read(&pack_path).unwrap();
            let idx = std::fs::read(PackFile::idx_path(&pack_path)).unwrap();
            std::fs::remove_dir_all(&dir).unwrap();
            (pack, idx, r.mark_payload_decodes)
        };
        let (pack_meta, idx_meta, decodes_meta) = run("bitid-meta", false);
        let (pack_oracle, idx_oracle, decodes_oracle) = run("bitid-oracle", true);
        assert_eq!(decodes_meta, 0, "metadata mark must be decode-free");
        assert!(decodes_oracle > 0);
        assert_eq!(pack_meta, pack_oracle, "pack bytes must be identical");
        assert_eq!(idx_meta, idx_oracle, "index bytes must be identical");
    }

    #[test]
    fn repack_requires_packed_backend() {
        let mut store = Store::in_memory();
        let id = hash_bytes(b"x");
        assert!(repack(&mut store, &[id], &RepackConfig::default(), &NativeKernel).is_err());
    }

    #[test]
    fn incremental_escalates_on_generation_count() {
        let (dir, mut store) = tmp_store("esc-gens");
        let ids = build_chain(&store, 3, 31);
        let mut tip = *ids.last().unwrap();
        let inc = RepackConfig {
            max_chain_depth: 16,
            prune: false,
            mode: RepackMode::Incremental,
            ..RepackConfig::default()
        };
        // Grow three pack generations (each run stages fresh loose links).
        repack(&mut store, &[tip], &inc, &NativeKernel).unwrap();
        for round in 0..2 {
            tip = *extend_chain(&store, tip, 2, 40 + round).last().unwrap();
            let r = repack(&mut store, &[tip], &inc, &NativeKernel).unwrap();
            assert!(r.escalated.is_none(), "thresholds disabled must never escalate");
        }
        assert_eq!(store.as_packed().unwrap().packs().len(), 3);
        let all: Vec<ObjectId> = store.list().unwrap();
        let want = resolve_all(&store, &all);

        // Next incremental run with a 2-generation budget promotes to a
        // full rewrite: one pack remains, content bit-identical.
        tip = *extend_chain(&store, tip, 1, 50).last().unwrap();
        let esc = RepackConfig { max_generations: Some(2), ..inc };
        let r = repack(&mut store, &[tip], &esc, &NativeKernel).unwrap();
        let reason = r.escalated.expect("3 generations > 2 must escalate");
        assert!(reason.contains("generations"), "unexpected reason: {reason}");
        assert_eq!(r.packs_after, 1);
        let store2 = Store::open_packed(&dir).unwrap();
        let got = resolve_all(&store2, &all);
        for (b, a) in want.iter().zip(&got) {
            for (x, y) in b.iter().zip(a) {
                assert_eq!(x.to_bits(), y.to_bits(), "content changed by escalation");
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn incremental_escalates_on_dead_byte_ratio() {
        let (dir, mut store) = tmp_store("esc-dead");
        let ids = build_chain(&store, 6, 33);
        let tip = *ids.last().unwrap();
        let full = RepackConfig {
            max_chain_depth: 8,
            prune: false,
            mode: RepackMode::Full,
            ..RepackConfig::default()
        };
        repack(&mut store, &[tip], &full, &NativeKernel).unwrap();

        // Re-root at the base: the six packed deltas become garbage an
        // incremental run could never reclaim. With a dead-ratio budget
        // the run promotes to full and (with prune) drops them.
        let esc = RepackConfig {
            max_chain_depth: 8,
            prune: true,
            mode: RepackMode::Incremental,
            max_dead_ratio: Some(0.1),
            ..RepackConfig::default()
        };
        let r = repack(&mut store, &[ids[0]], &esc, &NativeKernel).unwrap();
        let reason = r.escalated.expect("garbage past the ratio must escalate");
        assert!(reason.contains("dead-byte"), "unexpected reason: {reason}");
        assert!(r.dead_ratio > 0.1, "measured ratio {}", r.dead_ratio);
        assert_eq!(r.packs_after, 1);
        assert!(store.has(&ids[0]));
        assert!(!store.has(&tip), "pruned full rewrite drops packed garbage");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Store a raw tensor whose values sit exactly on the `k·step(eps)`
    /// grid (so any later grid re-encode is bit-exact). Returns its id.
    fn put_grid_raw(store: &Store, ks: &[i32], eps: f32) -> (ObjectId, Vec<f32>) {
        use crate::delta::quant;
        use crate::store::hash_tensor;
        use crate::tensor::DType;
        let s = quant::step(eps);
        let vals: Vec<f32> = ks.iter().map(|&k| k as f32 * s).collect();
        let payload = f32_to_bytes(&vals);
        let id = hash_tensor(DType::F32, &[vals.len()], &payload);
        store
            .put(
                id,
                &TensorObject::Raw { dtype: DType::F32, shape: vec![vals.len()], payload }
                    .encode(),
            )
            .unwrap();
        (id, vals)
    }

    /// Store a grid-mode delta of `child_ks` against `parent`. Returns
    /// the child's id and resolved values.
    fn put_grid_delta(
        store: &Store,
        parent: ObjectId,
        parent_ks: &[i32],
        child_ks: &[i32],
        eps: f32,
    ) -> (ObjectId, Vec<f32>) {
        use crate::delta::quant;
        use crate::store::hash_tensor;
        use crate::tensor::{i32_to_bytes, DType};
        let s = quant::step(eps);
        let codec = Codec::Deflate;
        let q: Vec<i32> = parent_ks.iter().zip(child_ks).map(|(&p, &c)| p - c).collect();
        let vals: Vec<f32> = child_ks.iter().map(|&k| k as f32 * s).collect();
        let payload = f32_to_bytes(&vals);
        let id = hash_tensor(DType::F32, &[vals.len()], &payload);
        let obj = TensorObject::Delta {
            dtype: DType::F32,
            shape: vec![vals.len()],
            parent,
            eps,
            codec: codec.code(),
            n_quant: vals.len(),
            grid: true,
            payload: codec.compress(&i32_to_bytes(&q)).unwrap(),
        };
        store.put(id, &obj.encode()).unwrap();
        (id, vals)
    }

    /// Deterministic pseudo-random grid coefficients.
    fn grid_ks(n: usize, seed: u64) -> Vec<i32> {
        let mut x = seed | 1;
        (0..n)
            .map(|_| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((x >> 33) % 2000) as i32 - 1000
            })
            .collect()
    }

    #[test]
    fn similarity_rebases_onto_similar_non_parent() {
        let (dir, mut store) = tmp_store("sim-golden");
        let len = 4096usize;
        let eps = 1e-4f32;
        // A: the lineage parent, content unrelated to the child.
        let ka = grid_ks(len, 7);
        // C: an unrelated raw object that happens to share almost all of
        // the child's content (cross-lineage near-duplicate).
        let kc = grid_ks(len, 99);
        // D: child of A by lineage, but nearly identical to C.
        let mut kd = kc.clone();
        for k in kd.iter_mut().take(16) {
            *k += 3;
        }
        let (a_id, _) = put_grid_raw(&store, &ka, eps);
        let (c_id, _) = put_grid_raw(&store, &kc, eps);
        let (d_id, d_vals) = put_grid_delta(&store, a_id, &ka, &kd, eps);

        let cfg = RepackConfig {
            mode: RepackMode::Full,
            similarity: Some(0.5),
            ..RepackConfig::default()
        };
        let report =
            repack(&mut store, &[d_id, c_id, a_id], &cfg, &NativeKernel).unwrap();
        assert_eq!(report.base_rewrites, 1, "report: {report:?}");

        // D now hangs off C, and still resolves bit-exactly.
        let meta = store.object_meta(&d_id).unwrap();
        assert_eq!(meta.parent, Some(c_id), "delta must re-base onto the similar object");
        let mut cache = HashMap::new();
        let got = delta::resolve_tensor(&store, d_id, &NativeKernel, &mut cache, 0).unwrap();
        assert_eq!(got.len(), d_vals.len());
        for (x, y) in d_vals.iter().zip(&got) {
            assert_eq!(x.to_bits(), y.to_bits(), "re-based delta changed content");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn similarity_drops_deltas_below_min_savings() {
        let (dir, mut store) = tmp_store("sim-skip");
        let ids = build_chain(&store, 3, 21);
        let before = resolve_all(&store, &ids);
        let cfg = RepackConfig {
            mode: RepackMode::Full,
            similarity: Some(0.0),
            min_savings: 0.99, // no real delta saves 99%
            ..RepackConfig::default()
        };
        let roots = vec![*ids.last().unwrap()];
        let report = repack(&mut store, &roots, &cfg, &NativeKernel).unwrap();
        assert_eq!(report.delta_skipped, 3, "report: {report:?}");
        assert_eq!(report.max_depth_after, 0, "every delta must be stored raw");
        let after = resolve_all(&store, &ids);
        for (b, a) in before.iter().zip(&after) {
            for (x, y) in b.iter().zip(a) {
                assert_eq!(x.to_bits(), y.to_bits(), "raw promotion changed content");
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn similarity_pass_preserves_content_and_depth_cap() {
        let (dir, mut store) = tmp_store("sim-cap");
        let ids = build_chain(&store, 12, 5);
        let before = resolve_all(&store, &ids);
        let cfg = RepackConfig {
            max_chain_depth: 4,
            mode: RepackMode::Full,
            similarity: Some(0.9),
            ..RepackConfig::default()
        };
        let roots = vec![*ids.last().unwrap()];
        let report = repack(&mut store, &roots, &cfg, &NativeKernel).unwrap();
        assert!(report.max_depth_after <= 4);
        let after = resolve_all(&store, &ids);
        for (b, a) in before.iter().zip(&after) {
            for (x, y) in b.iter().zip(a) {
                assert_eq!(x.to_bits(), y.to_bits(), "similarity pass changed content");
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn similarity_config_is_validated() {
        let (dir, mut store) = tmp_store("sim-val");
        let ids = build_chain(&store, 1, 3);
        let roots = vec![*ids.last().unwrap()];
        let bad_t = RepackConfig { similarity: Some(1.5), ..RepackConfig::default() };
        assert!(repack(&mut store, &roots, &bad_t, &NativeKernel).is_err());
        let bad_s = RepackConfig {
            similarity: Some(0.5),
            min_savings: 1.0,
            ..RepackConfig::default()
        };
        assert!(repack(&mut store, &roots, &bad_s, &NativeKernel).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
