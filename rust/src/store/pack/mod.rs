//! Pack files: many objects in one append-only file + a sidecar index.
//!
//! A loose store keeps one file per object — simple, but at production
//! scale (millions of tensors) directory fan-out breaks down: every cold
//! read is an `open()`, GC rewrites the whole tree, and small objects
//! waste a filesystem block each. Packs are git's answer, adapted to the
//! MGTF object model.
//!
//! ## On-disk formats (all integers little-endian)
//!
//! `pack-<sha256-hex>.pack`:
//!
//! ```text
//! magic   "MGPK"                          4 bytes
//! version u8 = 1
//! entries count ×:
//!     len u64                             object byte length
//!     bytes [len]                         MGTF object (or opaque blob)
//! count   u64                             entry count (trailer)
//! sha     32 bytes                        SHA-256 of everything above
//! ```
//!
//! `pack-<sha256-hex>.idx` (loadable without touching the pack):
//!
//! ```text
//! magic   "MGPI"                          4 bytes
//! version u8 = 1
//! count   u64
//! fanout  256 × u32                       cumulative count by id[0]
//! entries count × (sorted by id):
//!     id     32 bytes
//!     offset u64                          file offset of object bytes
//!     len    u64
//! sha     32 bytes                        the pack's trailer SHA-256
//! ```
//!
//! Lookup is fanout-bucketed binary search ([`PackIndex::lookup`]);
//! object reads are lock-free bounds-checked copies out of a
//! memory-mapped (or positionally-read) pack ([`PackFile::get`] over
//! [`PackMmap`]), so any number of threads can read one pack
//! concurrently. Packs are immutable once finished: [`PackWriter`]
//! streams objects into a temp file, then renames it to its content
//! hash. Compaction/chain re-basing lives in [`repack()`].

mod mmap;
mod repack;
mod writer;

pub use mmap::PackMmap;
pub use repack::{
    chain_depths, chain_depths_from_parents, repack, RepackConfig, RepackMode,
    RepackReport,
};
pub use writer::PackWriter;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use sha2::{Digest, Sha256};

use super::ObjectId;

pub const PACK_MAGIC: &[u8; 4] = b"MGPK";
pub const IDX_MAGIC: &[u8; 4] = b"MGPI";
pub const VERSION: u8 = 1;
/// Pack header length (magic + version): the first valid object offset
/// is `HEADER_LEN + 8` (past the first length prefix).
pub const HEADER_LEN: u64 = 5;
/// Pack trailer length (count + sha256).
pub const TRAILER_LEN: u64 = 8 + 32;

/// One object's position inside a pack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IdxEntry {
    pub id: ObjectId,
    /// Absolute file offset of the object bytes (past the len prefix).
    pub offset: u64,
    pub len: u64,
}

/// Sorted fan-out table over a pack's objects.
pub struct PackIndex {
    /// Sorted by id.
    pub entries: Vec<IdxEntry>,
    fanout: [u32; 256],
    /// The paired pack's trailer checksum.
    pub pack_sha: [u8; 32],
}

impl PackIndex {
    pub fn from_entries(mut entries: Vec<IdxEntry>, pack_sha: [u8; 32]) -> Result<PackIndex> {
        entries.sort_by(|a, b| a.id.cmp(&b.id));
        for w in entries.windows(2) {
            if w[0].id == w[1].id {
                bail!("duplicate object {} in pack index", w[0].id.short());
            }
        }
        let mut fanout = [0u32; 256];
        for e in &entries {
            fanout[e.id.0[0] as usize] += 1;
        }
        let mut acc = 0u32;
        for f in fanout.iter_mut() {
            acc += *f;
            *f = acc;
        }
        Ok(PackIndex { entries, fanout, pack_sha })
    }

    pub fn ids(&self) -> impl Iterator<Item = ObjectId> + '_ {
        self.entries.iter().map(|e| e.id)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Binary search within the id's fan-out bucket.
    pub fn lookup(&self, id: &ObjectId) -> Option<(u64, u64)> {
        let b = id.0[0] as usize;
        let lo = if b == 0 { 0 } else { self.fanout[b - 1] as usize };
        let hi = self.fanout[b] as usize;
        let seg = &self.entries[lo..hi];
        seg.binary_search_by(|e| e.id.cmp(id))
            .ok()
            .map(|i| (seg[i].offset, seg[i].len))
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(4 + 1 + 8 + 256 * 4 + self.entries.len() * 48 + 32);
        out.extend_from_slice(IDX_MAGIC);
        out.push(VERSION);
        out.extend_from_slice(&(self.entries.len() as u64).to_le_bytes());
        for f in &self.fanout {
            out.extend_from_slice(&f.to_le_bytes());
        }
        for e in &self.entries {
            out.extend_from_slice(&e.id.0);
            out.extend_from_slice(&e.offset.to_le_bytes());
            out.extend_from_slice(&e.len.to_le_bytes());
        }
        out.extend_from_slice(&self.pack_sha);
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<PackIndex> {
        let mut r = ByteReader { b: bytes, pos: 0 };
        if r.take(4)? != IDX_MAGIC {
            bail!("not an MGPI pack index");
        }
        let version = r.u8()?;
        if version != VERSION {
            bail!("unsupported pack index version {version}");
        }
        let count = r.u64()? as usize;
        for _ in 0..256 {
            r.u32()?; // fanout is re-derived from the entries below
        }
        let mut entries = Vec::with_capacity(count);
        for _ in 0..count {
            let mut id = [0u8; 32];
            id.copy_from_slice(r.take(32)?);
            let offset = r.u64()?;
            let len = r.u64()?;
            entries.push(IdxEntry { id: ObjectId(id), offset, len });
        }
        let mut pack_sha = [0u8; 32];
        pack_sha.copy_from_slice(r.take(32)?);
        if r.pos != bytes.len() {
            bail!("trailing bytes in pack index");
        }
        Self::from_entries(entries, pack_sha)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("idx.tmp");
        std::fs::write(&tmp, self.encode())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<PackIndex> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading pack index {}", path.display()))?;
        Self::decode(&bytes)
    }
}

/// An open pack: its index plus a lock-free reader over the pack bytes.
///
/// `PackFile` is `Send + Sync`: the index is immutable after load and
/// [`PackMmap`] reads need no coordination, so one handle serves any
/// number of concurrent reader threads without serializing them.
pub struct PackFile {
    /// Path of the sealed `.pack` file.
    pub path: PathBuf,
    /// The sidecar fan-out index.
    pub index: PackIndex,
    data: PackMmap,
}

impl PackFile {
    /// The sidecar index path for a `.pack` path.
    pub fn idx_path(pack_path: &Path) -> PathBuf {
        pack_path.with_extension("idx")
    }

    /// Open a sealed pack: load its index, map the pack bytes, and
    /// validate the header magic + version.
    pub fn open(pack_path: &Path) -> Result<PackFile> {
        let index = PackIndex::load(&Self::idx_path(pack_path))?;
        let data = PackMmap::open(pack_path)?;
        let header = data
            .read_at(0, HEADER_LEN as usize)
            .with_context(|| format!("reading pack header {}", pack_path.display()))?;
        if &header[..4] != PACK_MAGIC {
            bail!("{} is not an MGPK pack", pack_path.display());
        }
        if header[4] != VERSION {
            bail!("unsupported pack version {}", header[4]);
        }
        Ok(PackFile { path: pack_path.to_path_buf(), index, data })
    }

    /// Whether this pack holds `id` (index-only; the pack is untouched).
    pub fn contains(&self, id: &ObjectId) -> bool {
        self.index.lookup(id).is_some()
    }

    /// Read one object; `Ok(None)` if this pack doesn't hold `id`.
    /// Lock-free: concurrent `get`s never wait on each other.
    pub fn get(&self, id: &ObjectId) -> Result<Option<Vec<u8>>> {
        let Some((offset, len)) = self.index.lookup(id) else {
            return Ok(None);
        };
        let buf = self.data.read_at(offset, len as usize).with_context(|| {
            format!(
                "reading object {} at offset {offset} in pack {}",
                id.short(),
                self.path.display()
            )
        })?;
        Ok(Some(buf))
    }

    /// Number of objects in this pack.
    pub fn object_count(&self) -> usize {
        self.index.len()
    }

    /// Total pack file size in bytes (header + objects + trailer).
    pub fn size_bytes(&self) -> u64 {
        self.data.len()
    }

    /// The read strategy backing this pack: `"mmap"`, `"pread"` or
    /// `"locked"` (see [`PackMmap::kind`]).
    pub fn reader_kind(&self) -> &'static str {
        self.data.kind()
    }

    /// Structural verification: trailer checksum, entry count, and that
    /// every index entry points at a properly length-prefixed byte range.
    /// (Content-level verification — decoding objects and re-hashing
    /// resolved tensors — is `mgit verify-pack`'s job, since it needs
    /// chain resolution across the whole store.)
    pub fn verify(&self) -> Result<()> {
        let bytes = std::fs::read(&self.path)
            .with_context(|| format!("reading pack {}", self.path.display()))?;
        let total = bytes.len() as u64;
        if total < HEADER_LEN + TRAILER_LEN {
            bail!("pack {} truncated", self.path.display());
        }
        if &bytes[..4] != PACK_MAGIC || bytes[4] != VERSION {
            bail!("pack {} has a bad header", self.path.display());
        }
        let body_end = (total - 32) as usize;
        let mut h = Sha256::new();
        h.update(&bytes[..body_end]);
        let sha: [u8; 32] = h.finalize().into();
        if sha != bytes[body_end..] {
            bail!(
                "pack {} checksum mismatch over bytes 0..{body_end} \
                 (trailer at offset {body_end} does not match the body)",
                self.path.display()
            );
        }
        if sha != self.index.pack_sha {
            bail!(
                "index/pack checksum mismatch for {} (the .idx sidecar was \
                 written for a different pack body)",
                self.path.display()
            );
        }
        let count_off = (total - TRAILER_LEN) as usize;
        let count =
            u64::from_le_bytes(bytes[count_off..count_off + 8].try_into().unwrap()) as usize;
        if count != self.index.len() {
            bail!(
                "pack {} holds {} objects, index says {}",
                self.path.display(),
                count,
                self.index.len()
            );
        }
        for e in &self.index.entries {
            if e.offset < HEADER_LEN + 8 || e.offset + e.len > total - TRAILER_LEN {
                bail!(
                    "index entry {} (offset {}, len {}) out of bounds in pack {}",
                    e.id.short(),
                    e.offset,
                    e.len,
                    self.path.display()
                );
            }
            let lp = (e.offset - 8) as usize;
            let len = u64::from_le_bytes(bytes[lp..lp + 8].try_into().unwrap());
            if len != e.len {
                bail!(
                    "length prefix mismatch for {} at offset {} in pack {} \
                     ({} vs {})",
                    e.id.short(),
                    e.offset,
                    self.path.display(),
                    len,
                    e.len
                );
            }
        }
        Ok(())
    }
}

// Compile-time proof that the concurrent read tier is actually shareable:
// the whole pack layer must be Send + Sync for `PackedStore`/`Store` to
// fan chain reconstruction out across threads.
#[allow(dead_code)]
fn _assert_pack_types_send_sync() {
    fn check<T: Send + Sync>() {}
    check::<PackMmap>();
    check::<PackIndex>();
    check::<PackFile>();
}

struct ByteReader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("truncated pack data");
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::hash_bytes;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("mgit-pack-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn write_read_verify_roundtrip() {
        let dir = tmp_dir("roundtrip");
        let mut w = PackWriter::create(&dir).unwrap();
        let payloads: Vec<Vec<u8>> = (0..50u8)
            .map(|i| vec![i; 16 + (i as usize * 7) % 64])
            .collect();
        let ids: Vec<ObjectId> = payloads.iter().map(|p| hash_bytes(p)).collect();
        for (id, p) in ids.iter().zip(&payloads) {
            w.add(*id, p).unwrap();
        }
        let pack = w.finish().unwrap();
        assert_eq!(pack.object_count(), 50);
        pack.verify().unwrap();
        for (id, p) in ids.iter().zip(&payloads) {
            assert!(pack.contains(id));
            assert_eq!(pack.get(id).unwrap().unwrap(), *p);
        }
        assert!(pack.get(&hash_bytes(b"absent")).unwrap().is_none());

        // Re-open from disk (index loads without reading the pack body).
        let reopened = PackFile::open(&pack.path).unwrap();
        assert_eq!(reopened.object_count(), 50);
        reopened.verify().unwrap();
        for (id, p) in ids.iter().zip(&payloads) {
            assert_eq!(reopened.get(id).unwrap().unwrap(), *p);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn index_roundtrip_and_lookup() {
        let entries: Vec<IdxEntry> = (0..200u32)
            .map(|i| IdxEntry {
                id: hash_bytes(&i.to_le_bytes()),
                offset: 13 + i as u64 * 100,
                len: i as u64 + 1,
            })
            .collect();
        let idx = PackIndex::from_entries(entries.clone(), [7u8; 32]).unwrap();
        let back = PackIndex::decode(&idx.encode()).unwrap();
        assert_eq!(back.len(), 200);
        assert_eq!(back.pack_sha, [7u8; 32]);
        for e in &entries {
            assert_eq!(back.lookup(&e.id), Some((e.offset, e.len)));
        }
        assert_eq!(back.lookup(&hash_bytes(b"missing")), None);
    }

    #[test]
    fn duplicate_ids_rejected() {
        let id = hash_bytes(b"dup");
        let entries = vec![
            IdxEntry { id, offset: 13, len: 4 },
            IdxEntry { id, offset: 30, len: 4 },
        ];
        assert!(PackIndex::from_entries(entries, [0u8; 32]).is_err());
    }

    #[test]
    fn corruption_detected() {
        let dir = tmp_dir("corrupt");
        let mut w = PackWriter::create(&dir).unwrap();
        let id = hash_bytes(b"x");
        w.add(id, b"payload-bytes").unwrap();
        let pack = w.finish().unwrap();
        pack.verify().unwrap();
        // Flip one payload byte.
        let mut bytes = std::fs::read(&pack.path).unwrap();
        bytes[(HEADER_LEN + 8) as usize] ^= 0xff;
        std::fs::write(&pack.path, &bytes).unwrap();
        let reopened = PackFile::open(&pack.path).unwrap();
        assert!(reopened.verify().is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn abort_leaves_no_pack() {
        let dir = tmp_dir("abort");
        let mut w = PackWriter::create(&dir).unwrap();
        w.add(hash_bytes(b"y"), b"yy").unwrap();
        w.abort().unwrap();
        let left: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert!(left.is_empty(), "abort must remove the temp pack");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
