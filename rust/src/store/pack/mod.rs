//! Pack files: many objects in one append-only file + a sidecar index.
//!
//! A loose store keeps one file per object — simple, but at production
//! scale (millions of tensors) directory fan-out breaks down: every cold
//! read is an `open()`, GC rewrites the whole tree, and small objects
//! waste a filesystem block each. Packs are git's answer, adapted to the
//! MGTF object model.
//!
//! ## On-disk formats (all integers little-endian)
//!
//! `pack-<sha256-hex>.pack`, version 2 (current; see
//! `docs/STORAGE.md` for the byte-level tables and the frozen v1
//! layout, which stays readable forever):
//!
//! ```text
//! magic   "MGPK"                          4 bytes
//! version u8 = 2
//! framing u8                              0 = raw, 1 = zstd
//! -- framing = raw --
//! entries count ×:
//!     len u64                             object byte length
//!     bytes [len]                         MGTF object (or opaque blob)
//! -- framing = zstd --
//! ulen    u64                             uncompressed body length
//! zbytes                                  one zstd frame of the body
//!                                         (the same len-prefixed entries)
//! -- either way --
//! count   u64                             entry count (trailer)
//! sha     32 bytes                        SHA-256 of everything above
//! ```
//!
//! `pack-<sha256-hex>.idx`, version 3 (loadable without touching the
//! pack — and, since v2, walkable without *decoding* it):
//!
//! ```text
//! magic   "MGPI"                          4 bytes
//! version u8 = 3
//! count   u64
//! fanout  256 × u32                       cumulative count by id[0]
//! entries count × (sorted by id):
//!     id     32 bytes
//!     offset u64                          logical offset of object bytes
//!     len    u64
//!     kind   u8                           ObjectKind code (raw/delta/opaque)
//!     depth  u32                          delta-chain depth at pack time
//!     parent 32 bytes                     delta parent id (zeroed sentinel
//!                                         for raw/opaque base objects)
//!     numel  u64                          tensor element count (v3 only;
//!                                         0 for opaque blobs)
//! sha     32 bytes                        the pack's trailer SHA-256
//! ```
//!
//! The v2 entry's `kind`/`parent`/`depth` triple makes pack metadata
//! **self-describing**: incremental repack's mark phase and `fsck`'s
//! orphaned-parent scan walk delta-parent edges straight out of the
//! index, with zero payload decodes (counter-asserted in tests). Index
//! v3 appends each tensor's element count, so `stats`' parameter/
//! logical-byte totals become metadata walks too. Version-1 packs and
//! indexes (no framing byte, no entry metadata) and v2 indexes (no
//! numel) remain readable forever — the version byte dispatches — and
//! `repack --full` rewrites them to the current formats. The index
//! version ([`IDX_VERSION`]) evolves independently of the pack file
//! version ([`VERSION`]): a v2 pack normally pairs with a v3 index.
//!
//! Pack **v3** ([`VERSION_CHUNKED`], written only under `repack
//! --similarity` / chunk dedup) keeps the v2 header/trailer shape but
//! allows entries whose stored bytes are an `MGCR` chunk-ref [`recipe`]
//! instead of the object itself: a copy/literal program over earlier
//! byte ranges of the same pack, so regions shared across *unrelated*
//! objects are stored once. Its sidecar is index **v4** (94-byte
//! entries = the v3 layout + a trailing `enc` byte: 0 = inline object
//! bytes, 1 = recipe). [`PackFile::get`] reassembles recipes
//! transparently, so every layer above — `Store::get`, GC, fsck,
//! `mgit serve`, the remote tier — sees bit-exact original bytes.
//! Byte-level tables live in `docs/COMPRESSION.md`.
//!
//! Index/pack `offset`s are *logical*: for raw framing the logical image
//! is the file itself (reads stay on the mmap fast path); for zstd
//! framing it is the decoded header+body, materialized **lazily on the
//! first body read** into an owned buffer ([`PackMmap::from_owned`],
//! cached per handle) so readers are untouched by the framing choice and
//! commands that never read bodies never pay the decode.
//!
//! Lookup is fanout-bucketed binary search ([`PackIndex::lookup`]);
//! object reads are lock-free bounds-checked copies out of a
//! memory-mapped (or positionally-read, or owned) image
//! ([`PackFile::get`] over [`PackMmap`]), so any number of threads can
//! read one pack concurrently. Packs are immutable once finished:
//! [`PackWriter`] streams objects into a temp file, then renames it to
//! its content hash. Compaction/chain re-basing lives in [`repack()`].

mod mmap;
pub mod recipe;
mod repack;
mod writer;

pub use mmap::PackMmap;
pub use repack::{
    chain_depths, chain_depths_from_parents, repack, RepackConfig, RepackMode,
    RepackReport,
};
pub use writer::PackWriter;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use sha2::{Digest, Sha256};

use super::format::{ObjectKind, TensorObject};
use super::ObjectId;

pub const PACK_MAGIC: &[u8; 4] = b"MGPK";
pub const IDX_MAGIC: &[u8; 4] = b"MGPI";
/// The frozen first-generation format (no framing byte, no index
/// metadata). Still readable; never written anymore.
pub const VERSION_1: u8 = 1;
/// The current *pack file* write version (framing byte in the header).
pub const VERSION: u8 = 2;
/// Pack format v3: same header/trailer as v2, but entries may store an
/// `MGCR` chunk-ref [`recipe`] instead of the object bytes. Written
/// only when chunk dedup is enabled (`repack --similarity` /
/// `--chunk-dedup`); plain repacks keep writing v2.
pub const VERSION_CHUNKED: u8 = 3;
/// Index format v2: entries carry kind/parent/depth (85 bytes each).
/// Still readable; superseded by v3 for new writes.
pub const IDX_VERSION_2: u8 = 2;
/// The current *index* write version: v3 = v2 + persisted tensor numel
/// (93-byte entries). The sidecar index evolves independently of the
/// pack body — a v2 pack file normally pairs with a v3 index.
pub const IDX_VERSION: u8 = 3;
/// Index format v4: v3 + a trailing per-entry `enc` byte (0 = inline
/// object bytes, 1 = `MGCR` recipe; 94-byte entries). Chosen
/// automatically by [`PackIndex::from_entries`] whenever any entry is a
/// recipe, so recipe-free packs keep producing v3 indexes byte for
/// byte.
pub const IDX_VERSION_4: u8 = 4;
/// Pack trailer length (count + sha256), identical in both versions.
pub const TRAILER_LEN: u64 = 8 + 32;

/// Pack header length for a format version: the first valid object
/// offset is `header_len(v) + 8` (past the first length prefix).
pub fn header_len(version: u8) -> u64 {
    match version {
        VERSION_1 => 5, // magic + version
        _ => 6,         // magic + version + framing
    }
}

/// Outer (whole-pack) framing, negotiated via the v2 pack-header flag.
///
/// Object payloads are already codec-compressed individually
/// ([`crate::delta::Codec`]), so raw framing is the default — it keeps
/// the zero-copy mmap read path. Zstd framing trades open-time
/// decompression (the pack decodes to an owned buffer once) for extra
/// whole-pack compression of everything the per-object codecs leave on
/// the table: MGTF headers, length prefixes, and cross-object
/// redundancy. It requires the feature-gated `zstd` dependency
/// (`--features zstd`); a build without it writes and reads raw packs
/// only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PackFraming {
    /// Body bytes stored verbatim; reads are served from the file
    /// (mmap/pread). The offline default.
    #[default]
    Raw,
    /// Body stored as a single zstd frame; decoded to an owned buffer
    /// at open.
    Zstd,
}

impl PackFraming {
    pub fn code(self) -> u8 {
        match self {
            PackFraming::Raw => 0,
            PackFraming::Zstd => 1,
        }
    }

    pub fn from_code(c: u8) -> Result<PackFraming> {
        match c {
            0 => Ok(PackFraming::Raw),
            1 => Ok(PackFraming::Zstd),
            _ => bail!("unknown pack framing code {c}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PackFraming::Raw => "raw",
            PackFraming::Zstd => "zstd",
        }
    }

    /// Parse a user-facing name (`repack --framing raw|zstd`).
    pub fn parse(name: &str) -> Result<PackFraming> {
        match name.to_ascii_lowercase().as_str() {
            "raw" => Ok(PackFraming::Raw),
            "zstd" => Ok(PackFraming::Zstd),
            other => bail!("unknown pack framing `{other}` (raw|zstd)"),
        }
    }
}

/// Per-entry object metadata persisted in index v2+: enough to walk
/// delta chains — and, since v3, to total tensor parameters — without
/// reading the pack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EntryMeta {
    pub kind: ObjectKind,
    /// Delta parent; `None` (a zeroed sentinel on disk) for raw/opaque
    /// base objects.
    pub parent: Option<ObjectId>,
    /// Chain depth of this pack's copy at write time. Exact for
    /// repack-written live objects (the repacker knows global depths);
    /// best-effort for objects added without explicit metadata (0 for
    /// bases, a lower bound for deltas whose parents live outside the
    /// pack). Never used for correctness — parents are.
    pub depth: u32,
    /// Tensor element count, persisted since index v3 (`stats`' logical
    /// byte totals walk this instead of reading object headers).
    /// `Some(0)` for opaque entries; `None` only when decoded from a v2
    /// index, which predates the field.
    pub numel: Option<u64>,
}

impl EntryMeta {
    /// Derive metadata from object bytes (header parse only).
    /// `parent_depth` resolves an in-pack parent's depth when known.
    pub fn infer(bytes: &[u8], parent_depth: impl Fn(&ObjectId) -> Option<u32>) -> EntryMeta {
        let meta = TensorObject::decode_meta(bytes);
        let depth = match (meta.kind, meta.parent.as_ref()) {
            (ObjectKind::Delta, Some(p)) => parent_depth(p).map_or(1, |d| d + 1),
            _ => 0,
        };
        let numel = Some(meta.numel.unwrap_or(0));
        EntryMeta { kind: meta.kind, parent: meta.parent, depth, numel }
    }
}

/// One object's position inside a pack (plus, in v2 indexes, its chain
/// metadata).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IdxEntry {
    pub id: ObjectId,
    /// Logical offset of the object bytes (past the len prefix).
    pub offset: u64,
    pub len: u64,
    /// `None` only for entries decoded from a v1 index.
    pub meta: Option<EntryMeta>,
    /// True when the stored bytes at `offset..offset+len` are an `MGCR`
    /// chunk-ref [`recipe`] rather than the object itself (index v4;
    /// always false for entries decoded from older indexes).
    pub recipe: bool,
}

/// Sorted fan-out table over a pack's objects.
pub struct PackIndex {
    /// Sorted by id.
    pub entries: Vec<IdxEntry>,
    fanout: [u32; 256],
    /// The paired pack's trailer checksum.
    pub pack_sha: [u8; 32],
    /// Index format version this was decoded from / will encode as:
    /// [`IDX_VERSION_4`] when any entry is a chunk-ref recipe,
    /// [`IDX_VERSION`] when every entry carries metadata including
    /// numel, [`IDX_VERSION_2`] when metadata lacks numel (decoded from
    /// a v2 index), [`VERSION_1`] otherwise.
    pub version: u8,
}

impl PackIndex {
    pub fn from_entries(mut entries: Vec<IdxEntry>, pack_sha: [u8; 32]) -> Result<PackIndex> {
        entries.sort_by(|a, b| a.id.cmp(&b.id));
        for w in entries.windows(2) {
            if w[0].id == w[1].id {
                bail!("duplicate object {} in pack index", w[0].id.short());
            }
        }
        let mut fanout = [0u32; 256];
        for e in &entries {
            fanout[e.id.0[0] as usize] += 1;
        }
        let mut acc = 0u32;
        for f in fanout.iter_mut() {
            acc += *f;
            *f = acc;
        }
        let any_recipe = entries.iter().any(|e| e.recipe);
        if any_recipe && !entries.iter().all(|e| e.meta.is_some_and(|m| m.numel.is_some())) {
            // Recipes only come from the chunk-dedup writer, which always
            // supplies full metadata; anything else is a corrupt index.
            bail!("recipe entry without full metadata in pack index");
        }
        let version = if any_recipe {
            IDX_VERSION_4
        } else if entries.iter().all(|e| e.meta.is_some()) {
            if entries.iter().all(|e| e.meta.is_some_and(|m| m.numel.is_some())) {
                IDX_VERSION
            } else {
                // Round-tripping a v2 index must not invent numel
                // values it never had: stay v2.
                IDX_VERSION_2
            }
        } else {
            VERSION_1
        };
        Ok(PackIndex { entries, fanout, pack_sha, version })
    }

    pub fn ids(&self) -> impl Iterator<Item = ObjectId> + '_ {
        self.entries.iter().map(|e| e.id)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Binary search within the id's fan-out bucket.
    pub fn lookup(&self, id: &ObjectId) -> Option<(u64, u64)> {
        self.entry(id).map(|e| (e.offset, e.len))
    }

    /// The full index entry for `id` (metadata included), if present.
    pub fn entry(&self, id: &ObjectId) -> Option<&IdxEntry> {
        let b = id.0[0] as usize;
        let lo = if b == 0 { 0 } else { self.fanout[b - 1] as usize };
        let hi = self.fanout[b] as usize;
        let seg = &self.entries[lo..hi];
        seg.binary_search_by(|e| e.id.cmp(id)).ok().map(|i| &seg[i])
    }

    pub fn encode(&self) -> Vec<u8> {
        let entry_len = match self.version {
            VERSION_1 => 48,
            IDX_VERSION_2 => 85,
            IDX_VERSION_4 => 94,
            _ => 93,
        };
        let mut out =
            Vec::with_capacity(4 + 1 + 8 + 256 * 4 + self.entries.len() * entry_len + 32);
        out.extend_from_slice(IDX_MAGIC);
        out.push(self.version);
        out.extend_from_slice(&(self.entries.len() as u64).to_le_bytes());
        for f in &self.fanout {
            out.extend_from_slice(&f.to_le_bytes());
        }
        for e in &self.entries {
            out.extend_from_slice(&e.id.0);
            out.extend_from_slice(&e.offset.to_le_bytes());
            out.extend_from_slice(&e.len.to_le_bytes());
            if self.version != VERSION_1 {
                // from_entries guarantees meta for v2/v3.
                let m = e.meta.expect("v2+ index entry without metadata");
                out.push(m.kind.code());
                out.extend_from_slice(&m.depth.to_le_bytes());
                out.extend_from_slice(&m.parent.map_or([0u8; 32], |p| p.0));
                if self.version == IDX_VERSION || self.version == IDX_VERSION_4 {
                    // from_entries guarantees numel for v3/v4.
                    let n = m.numel.expect("v3+ index entry without numel");
                    out.extend_from_slice(&n.to_le_bytes());
                }
                if self.version == IDX_VERSION_4 {
                    out.push(e.recipe as u8);
                }
            }
        }
        out.extend_from_slice(&self.pack_sha);
        out
    }

    pub fn decode(bytes: &[u8]) -> Result<PackIndex> {
        let mut r = ByteReader { b: bytes, pos: 0 };
        if r.take(4)? != IDX_MAGIC {
            bail!("not an MGPI pack index");
        }
        let version = r.u8()?;
        if version != VERSION_1
            && version != IDX_VERSION_2
            && version != IDX_VERSION
            && version != IDX_VERSION_4
        {
            bail!("unsupported pack index version {version}");
        }
        let count = r.u64()? as usize;
        for _ in 0..256 {
            r.u32()?; // fanout is re-derived from the entries below
        }
        let mut entries = Vec::with_capacity(count);
        for _ in 0..count {
            let mut id = [0u8; 32];
            id.copy_from_slice(r.take(32)?);
            let offset = r.u64()?;
            let len = r.u64()?;
            let meta = if version == VERSION_1 {
                None
            } else {
                let kind = ObjectKind::from_code(r.u8()?)?;
                let depth = r.u32()?;
                let mut parent = [0u8; 32];
                parent.copy_from_slice(r.take(32)?);
                let parent = match kind {
                    ObjectKind::Delta => Some(ObjectId(parent)),
                    _ => None,
                };
                let numel = if version == IDX_VERSION || version == IDX_VERSION_4 {
                    Some(r.u64()?)
                } else {
                    None
                };
                Some(EntryMeta { kind, parent, depth, numel })
            };
            let recipe = if version == IDX_VERSION_4 {
                match r.u8()? {
                    0 => false,
                    1 => true,
                    other => bail!("unknown index entry encoding {other}"),
                }
            } else {
                false
            };
            entries.push(IdxEntry { id: ObjectId(id), offset, len, meta, recipe });
        }
        let mut pack_sha = [0u8; 32];
        pack_sha.copy_from_slice(r.take(32)?);
        if r.pos != bytes.len() {
            bail!("trailing bytes in pack index");
        }
        Self::from_entries(entries, pack_sha)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let tmp = path.with_extension("idx.tmp");
        std::fs::write(&tmp, self.encode())?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<PackIndex> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading pack index {}", path.display()))?;
        Self::decode(&bytes)
    }
}

/// An open pack: its index plus a lock-free reader over the pack's
/// *logical* bytes (the file itself for raw framing; for zstd framing,
/// an owned buffer decoded **lazily on first read** and cached for the
/// handle's lifetime).
///
/// Laziness matters twice over: commands that never touch this pack's
/// bodies (`mgit log`, index-metadata walks) pay nothing, and a corrupt
/// or feature-unsupported zstd body does not make the *store*
/// unopenable — `open` still succeeds, reads of that pack error
/// per-object, and `fsck`/`verify-pack` keep their contract of
/// reporting a bad pack instead of dying on it.
///
/// `PackFile` is `Send + Sync`: the index is immutable after load,
/// [`PackMmap`] reads need no coordination, and the decoded image sits
/// behind a `OnceLock`, so one handle serves any number of concurrent
/// reader threads without serializing them.
pub struct PackFile {
    /// Path of the sealed `.pack` file.
    pub path: PathBuf,
    /// The sidecar fan-out index.
    pub index: PackIndex,
    /// Pack format version (1, 2, or 3 = chunk-dedup recipes allowed).
    pub version: u8,
    /// Outer framing (always [`PackFraming::Raw`] for v1 packs).
    pub framing: PackFraming,
    /// The physical file bytes (logical image too, for raw framing).
    data: PackMmap,
    /// Zstd framing only: the decoded logical image, materialized on
    /// first body read. Decode errors are cached as strings (packs are
    /// immutable, so a failure is permanent for this handle).
    decoded: std::sync::OnceLock<std::result::Result<PackMmap, String>>,
}

impl PackFile {
    /// The sidecar index path for a `.pack` path.
    pub fn idx_path(pack_path: &Path) -> PathBuf {
        pack_path.with_extension("idx")
    }

    /// Open a sealed pack: load its index, map the pack bytes, and
    /// validate the header magic + version + framing code. Zstd-framed
    /// bodies are *not* decoded here — that happens on first read, so a
    /// bad body degrades to per-object read errors (and `BAD_PACK` in
    /// fsck) rather than an unopenable store.
    pub fn open(pack_path: &Path) -> Result<PackFile> {
        let index = PackIndex::load(&Self::idx_path(pack_path))?;
        let data = PackMmap::open(pack_path)?;
        let head = data
            .read_at(0, 5)
            .with_context(|| format!("reading pack header {}", pack_path.display()))?;
        if &head[..4] != PACK_MAGIC {
            bail!("{} is not an MGPK pack", pack_path.display());
        }
        let version = head[4];
        let framing = match version {
            VERSION_1 => PackFraming::Raw,
            VERSION | VERSION_CHUNKED => PackFraming::from_code(data.read_at(5, 1)?[0])
                .with_context(|| format!("pack {}", pack_path.display()))?,
            other => bail!("unsupported pack version {other}"),
        };
        Ok(PackFile {
            path: pack_path.to_path_buf(),
            index,
            version,
            framing,
            data,
            decoded: std::sync::OnceLock::new(),
        })
    }

    /// The reader serving this pack's *logical* image: the file itself
    /// for raw framing, the lazily decoded (and cached) buffer for zstd.
    fn logical(&self) -> Result<&PackMmap> {
        match self.framing {
            PackFraming::Raw => Ok(&self.data),
            PackFraming::Zstd => {
                let cached = self.decoded.get_or_init(|| {
                    Self::decode_zstd_image(&self.path, &self.data, self.version)
                        .map_err(|e| format!("{e:#}"))
                });
                match cached {
                    Ok(m) => Ok(m),
                    Err(e) => bail!("{e}"),
                }
            }
        }
    }

    /// Materialize a zstd-framed pack's logical image (header + decoded
    /// body) as an owned read buffer.
    #[cfg(feature = "zstd")]
    fn decode_zstd_image(pack_path: &Path, data: &PackMmap, version: u8) -> Result<PackMmap> {
        let hlen = header_len(version);
        let total = data.len();
        if total < hlen + 8 + TRAILER_LEN {
            bail!("zstd pack {} truncated", pack_path.display());
        }
        let ulen =
            u64::from_le_bytes(data.read_at(hlen, 8)?.try_into().unwrap());
        let zlen = (total - hlen - 8 - TRAILER_LEN) as usize;
        let zbytes = data.read_at(hlen + 8, zlen)?;
        let body = zstd::stream::decode_all(&zbytes[..]).with_context(|| {
            format!("decoding zstd pack body {}", pack_path.display())
        })?;
        if body.len() as u64 != ulen {
            bail!(
                "zstd pack {} decoded to {} bytes, header says {ulen}",
                pack_path.display(),
                body.len()
            );
        }
        let mut image = Vec::with_capacity(hlen as usize + body.len());
        image.extend_from_slice(PACK_MAGIC);
        image.push(version);
        image.push(PackFraming::Zstd.code());
        image.extend_from_slice(&body);
        Ok(PackMmap::from_owned(image))
    }

    #[cfg(not(feature = "zstd"))]
    fn decode_zstd_image(pack_path: &Path, _data: &PackMmap, _version: u8) -> Result<PackMmap> {
        bail!(
            "pack {} uses zstd outer framing, but this build has no zstd \
             support (rebuild with --features zstd)",
            pack_path.display()
        )
    }

    /// Whether this pack holds `id` (index-only; the pack is untouched).
    pub fn contains(&self, id: &ObjectId) -> bool {
        self.index.lookup(id).is_some()
    }

    /// Read one object; `Ok(None)` if this pack doesn't hold `id`.
    /// Lock-free: concurrent `get`s never wait on each other (the first
    /// read of a zstd-framed pack decodes its body once, under the
    /// `OnceLock`). Chunk-ref recipe entries (pack v3) are reassembled
    /// here, so callers always receive the bit-exact original bytes.
    pub fn get(&self, id: &ObjectId) -> Result<Option<Vec<u8>>> {
        let Some(e) = self.index.entry(id) else {
            return Ok(None);
        };
        let (offset, len) = (e.offset, e.len);
        let image = self.logical()?;
        let buf = image.read_at(offset, len as usize).with_context(|| {
            format!(
                "reading object {} at offset {offset} in pack {}",
                id.short(),
                self.path.display()
            )
        })?;
        if !e.recipe {
            return Ok(Some(buf));
        }
        let r = recipe::Recipe::decode(&buf).with_context(|| {
            format!(
                "decoding chunk recipe for {} at offset {offset} in pack {}",
                id.short(),
                self.path.display()
            )
        })?;
        let out = r.reassemble(|src, n| image.read_at(src, n)).with_context(|| {
            format!(
                "reassembling {} from chunk recipe in pack {}",
                id.short(),
                self.path.display()
            )
        })?;
        Ok(Some(out))
    }

    /// Number of objects in this pack.
    pub fn object_count(&self) -> usize {
        self.index.len()
    }

    /// Pack file size on disk in bytes (the compressed size for
    /// zstd-framed packs).
    pub fn size_bytes(&self) -> u64 {
        self.data.len()
    }

    /// The read strategy backing this pack's object reads: `"mmap"`,
    /// `"pread"` or `"locked"` for raw framing (see [`PackMmap::kind`]),
    /// `"owned"` for zstd framing (reads come from the decoded buffer).
    pub fn reader_kind(&self) -> &'static str {
        match self.framing {
            PackFraming::Raw => self.data.kind(),
            PackFraming::Zstd => "owned",
        }
    }

    /// Structural verification: trailer checksum, entry count, that
    /// every index entry points at a properly length-prefixed byte
    /// range of the logical image, and — for v2 indexes — that each
    /// entry's persisted kind/parent metadata agrees with the object
    /// header actually stored in the pack. (Content-level verification —
    /// decoding objects and re-hashing resolved tensors — is
    /// `mgit verify-pack`'s job, since it needs chain resolution across
    /// the whole store.)
    pub fn verify(&self) -> Result<()> {
        let bytes = std::fs::read(&self.path)
            .with_context(|| format!("reading pack {}", self.path.display()))?;
        let total = bytes.len() as u64;
        let hlen = header_len(self.version);
        if total < hlen + TRAILER_LEN {
            bail!("pack {} truncated", self.path.display());
        }
        if &bytes[..4] != PACK_MAGIC || bytes[4] != self.version {
            bail!("pack {} has a bad header", self.path.display());
        }
        // The trailer checksum covers the *physical* bytes, whatever the
        // framing — it seals the file as written.
        let body_end = (total - 32) as usize;
        let mut h = Sha256::new();
        h.update(&bytes[..body_end]);
        let sha: [u8; 32] = h.finalize().into();
        if sha != bytes[body_end..] {
            bail!(
                "pack {} checksum mismatch over bytes 0..{body_end} \
                 (trailer at offset {body_end} does not match the body)",
                self.path.display()
            );
        }
        if sha != self.index.pack_sha {
            bail!(
                "index/pack checksum mismatch for {} (the .idx sidecar was \
                 written for a different pack body)",
                self.path.display()
            );
        }
        let count_off = (total - TRAILER_LEN) as usize;
        let count =
            u64::from_le_bytes(bytes[count_off..count_off + 8].try_into().unwrap()) as usize;
        if count != self.index.len() {
            bail!(
                "pack {} holds {} objects, index says {}",
                self.path.display(),
                count,
                self.index.len()
            );
        }
        // Entry checks run against the logical image: the raw file body
        // is already in `bytes`; a zstd body is served from the lazily
        // cached decoded buffer — never copied wholesale a second time
        // (small per-entry reads only).
        let zimage = match self.framing {
            PackFraming::Raw => None,
            // The physical bytes this image came from were just
            // checksum-validated above.
            PackFraming::Zstd => Some(self.logical()?),
        };
        let body_limit = match zimage {
            None => total - TRAILER_LEN,
            Some(image) => image.len(),
        };
        let read_logical = |offset: u64, len: usize| -> Result<Vec<u8>> {
            match zimage {
                None => Ok(bytes[offset as usize..offset as usize + len].to_vec()),
                Some(image) => image.read_at(offset, len),
            }
        };
        // An MGTF header is at most magic+version+enc+dtype+ndim (8) +
        // 255 dims (2040) + parent/eps/codec/nquant (45) bytes; reading
        // that much is always enough for `decode_meta`.
        const MAX_HEADER: u64 = 8 + 255 * 8 + 45;
        for e in &self.index.entries {
            // checked_add: a corrupt index must produce a reportable
            // error, never a wrapped bound that slips through to a
            // slicing panic below.
            let in_bounds = e.offset >= hlen + 8
                && e.offset.checked_add(e.len).is_some_and(|end| end <= body_limit);
            if !in_bounds {
                bail!(
                    "index entry {} (offset {}, len {}) out of bounds in pack {}",
                    e.id.short(),
                    e.offset,
                    e.len,
                    self.path.display()
                );
            }
            let prefix = read_logical(e.offset - 8, 8)?;
            let len = u64::from_le_bytes(prefix.try_into().unwrap());
            if len != e.len {
                bail!(
                    "length prefix mismatch for {} at offset {} in pack {} \
                     ({} vs {})",
                    e.id.short(),
                    e.offset,
                    self.path.display(),
                    len,
                    e.len
                );
            }
            // Recipe entries store an MGCR program, not the object: the
            // program itself must be well-formed and every copy source
            // must lie strictly before this entry (one-pass, acyclic
            // reassembly), and the metadata check below runs against the
            // *reassembled* bytes.
            let reassembled = if e.recipe {
                let raw = read_logical(e.offset, e.len as usize)?;
                let r = recipe::Recipe::decode(&raw).with_context(|| {
                    format!(
                        "bad chunk recipe for {} at offset {} in pack {}",
                        e.id.short(),
                        e.offset,
                        self.path.display()
                    )
                })?;
                for (src, n) in r.copy_ranges() {
                    let ok = src.checked_add(n).is_some_and(|end| end <= e.offset)
                        && src >= hlen + 8;
                    if !ok {
                        bail!(
                            "recipe for {} in pack {} copies {n} bytes from \
                             offset {src}, outside the strictly-earlier range",
                            e.id.short(),
                            self.path.display()
                        );
                    }
                }
                Some(r.reassemble(|src, n| read_logical(src, n)).with_context(|| {
                    format!(
                        "recipe for {} in pack {} does not reassemble",
                        e.id.short(),
                        self.path.display()
                    )
                })?)
            } else {
                None
            };
            if let Some(meta) = e.meta {
                // The persisted chain metadata must describe the bytes:
                // a lying index would silently corrupt every
                // metadata-only walk (repack marking, fsck).
                let head = match &reassembled {
                    Some(b) => b[..(b.len() as u64).min(MAX_HEADER) as usize].to_vec(),
                    None => read_logical(e.offset, e.len.min(MAX_HEADER) as usize)?,
                };
                let actual = TensorObject::decode_meta(&head);
                if actual.kind != meta.kind || actual.parent != meta.parent {
                    bail!(
                        "index metadata mismatch for {} in pack {}: index says \
                         {}/{}, object header says {}/{}",
                        e.id.short(),
                        self.path.display(),
                        meta.kind.name(),
                        meta.parent.map_or("-".into(), |p| p.short()),
                        actual.kind.name(),
                        actual.parent.map_or("-".into(), |p| p.short()),
                    );
                }
                // v3 indexes also persist numel; a lying value would
                // silently skew every metadata-only parameter total.
                if let Some(n) = meta.numel {
                    let actual_n = actual.numel.unwrap_or(0);
                    if n != actual_n {
                        bail!(
                            "index numel mismatch for {} in pack {}: index says \
                             {n}, object header says {actual_n}",
                            e.id.short(),
                            self.path.display(),
                        );
                    }
                }
            }
        }
        Ok(())
    }
}

// Compile-time proof that the concurrent read tier is actually shareable:
// the whole pack layer must be Send + Sync for `PackedStore`/`Store` to
// fan chain reconstruction out across threads.
#[allow(dead_code)]
fn _assert_pack_types_send_sync() {
    fn check<T: Send + Sync>() {}
    check::<PackMmap>();
    check::<PackIndex>();
    check::<PackFile>();
}

struct ByteReader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.b.len() {
            bail!("truncated pack data");
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::hash_bytes;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("mgit-pack-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn write_read_verify_roundtrip() {
        let dir = tmp_dir("roundtrip");
        let mut w = PackWriter::create(&dir).unwrap();
        let payloads: Vec<Vec<u8>> = (0..50u8)
            .map(|i| vec![i; 16 + (i as usize * 7) % 64])
            .collect();
        let ids: Vec<ObjectId> = payloads.iter().map(|p| hash_bytes(p)).collect();
        for (id, p) in ids.iter().zip(&payloads) {
            w.add(*id, p).unwrap();
        }
        let pack = w.finish().unwrap();
        assert_eq!(pack.object_count(), 50);
        assert_eq!(pack.version, VERSION);
        assert_eq!(pack.framing, PackFraming::Raw);
        assert_eq!(pack.index.version, IDX_VERSION);
        pack.verify().unwrap();
        for (id, p) in ids.iter().zip(&payloads) {
            assert!(pack.contains(id));
            assert_eq!(pack.get(id).unwrap().unwrap(), *p);
            // These payloads are not MGTF objects, so the metadata must
            // classify them as opaque bases (numel 0).
            let meta = pack.index.entry(id).unwrap().meta.unwrap();
            assert_eq!(meta.kind, ObjectKind::Opaque);
            assert_eq!(meta.parent, None);
            assert_eq!(meta.depth, 0);
            assert_eq!(meta.numel, Some(0));
        }
        assert!(pack.get(&hash_bytes(b"absent")).unwrap().is_none());

        // Re-open from disk (index loads without reading the pack body).
        let reopened = PackFile::open(&pack.path).unwrap();
        assert_eq!(reopened.object_count(), 50);
        reopened.verify().unwrap();
        for (id, p) in ids.iter().zip(&payloads) {
            assert_eq!(reopened.get(id).unwrap().unwrap(), *p);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[cfg(feature = "zstd")]
    #[test]
    fn zstd_framing_roundtrip() {
        let dir = tmp_dir("zstd");
        let mut w = PackWriter::create_with(&dir, PackFraming::Zstd).unwrap();
        let payloads: Vec<Vec<u8>> = (0..40u8)
            .map(|i| vec![i % 5; 64 + (i as usize * 11) % 128])
            .collect();
        let ids: Vec<ObjectId> = payloads.iter().map(|p| hash_bytes(p)).collect();
        for (id, p) in ids.iter().zip(&payloads) {
            w.add(*id, p).unwrap();
        }
        let pack = w.finish().unwrap();
        assert_eq!(pack.framing, PackFraming::Zstd);
        assert_eq!(pack.reader_kind(), "owned");
        assert!(pack.decoded.get().is_none(), "body must not decode at open");
        pack.verify().unwrap();
        // Redundant payloads: the framed pack must be smaller on disk
        // than its logical image (decoded lazily by verify above).
        assert!(pack.size_bytes() < pack.logical().unwrap().len());
        for (id, p) in ids.iter().zip(&payloads) {
            assert_eq!(pack.get(id).unwrap().unwrap(), *p);
        }
        let reopened = PackFile::open(&pack.path).unwrap();
        reopened.verify().unwrap();
        for (id, p) in ids.iter().zip(&payloads) {
            assert_eq!(reopened.get(id).unwrap().unwrap(), *p);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn index_roundtrip_and_lookup_v1_and_v2() {
        // v1: no metadata.
        let entries: Vec<IdxEntry> = (0..200u32)
            .map(|i| IdxEntry {
                id: hash_bytes(&i.to_le_bytes()),
                offset: 13 + i as u64 * 100,
                len: i as u64 + 1,
                meta: None,
                recipe: false,
            })
            .collect();
        let idx = PackIndex::from_entries(entries.clone(), [7u8; 32]).unwrap();
        assert_eq!(idx.version, VERSION_1);
        let back = PackIndex::decode(&idx.encode()).unwrap();
        assert_eq!(back.len(), 200);
        assert_eq!(back.version, VERSION_1);
        assert_eq!(back.pack_sha, [7u8; 32]);
        for e in &entries {
            assert_eq!(back.lookup(&e.id), Some((e.offset, e.len)));
            assert_eq!(back.entry(&e.id).unwrap().meta, None);
        }
        assert_eq!(back.lookup(&hash_bytes(b"missing")), None);

        // v2: kind/parent/depth survive the roundtrip; numel was never
        // persisted, so the re-encoded index must *stay* v2 rather than
        // inventing values.
        let parent = hash_bytes(b"the-parent");
        let v2: Vec<IdxEntry> = (0..50u32)
            .map(|i| IdxEntry {
                id: hash_bytes(&(1000 + i).to_le_bytes()),
                offset: 14 + i as u64 * 64,
                len: 32,
                meta: Some(if i % 3 == 0 {
                    EntryMeta { kind: ObjectKind::Raw, parent: None, depth: 0, numel: None }
                } else {
                    EntryMeta {
                        kind: ObjectKind::Delta,
                        parent: Some(parent),
                        depth: i % 7,
                        numel: None,
                    }
                }),
                recipe: false,
            })
            .collect();
        let idx = PackIndex::from_entries(v2.clone(), [9u8; 32]).unwrap();
        assert_eq!(idx.version, IDX_VERSION_2);
        let back = PackIndex::decode(&idx.encode()).unwrap();
        assert_eq!(back.version, IDX_VERSION_2);
        for e in &v2 {
            assert_eq!(back.entry(&e.id).unwrap().meta, e.meta);
        }

        // v3: numel survives the roundtrip too.
        let v3: Vec<IdxEntry> = (0..50u32)
            .map(|i| IdxEntry {
                id: hash_bytes(&(2000 + i).to_le_bytes()),
                offset: 14 + i as u64 * 64,
                len: 32,
                meta: Some(EntryMeta {
                    kind: if i % 3 == 0 { ObjectKind::Raw } else { ObjectKind::Delta },
                    parent: (i % 3 != 0).then_some(parent),
                    depth: i % 7,
                    numel: Some(i as u64 * 17),
                }),
                recipe: false,
            })
            .collect();
        let idx = PackIndex::from_entries(v3.clone(), [11u8; 32]).unwrap();
        assert_eq!(idx.version, IDX_VERSION);
        let back = PackIndex::decode(&idx.encode()).unwrap();
        assert_eq!(back.version, IDX_VERSION);
        for e in &v3 {
            assert_eq!(back.entry(&e.id).unwrap().meta, e.meta);
        }

        // v4: one recipe entry upgrades the whole index, and the
        // per-entry enc flag survives the roundtrip.
        let v4: Vec<IdxEntry> = (0..50u32)
            .map(|i| IdxEntry {
                id: hash_bytes(&(3000 + i).to_le_bytes()),
                offset: 14 + i as u64 * 64,
                len: 32,
                meta: Some(EntryMeta {
                    kind: ObjectKind::Raw,
                    parent: None,
                    depth: 0,
                    numel: Some(i as u64),
                }),
                recipe: i % 5 == 0,
            })
            .collect();
        let idx = PackIndex::from_entries(v4.clone(), [13u8; 32]).unwrap();
        assert_eq!(idx.version, IDX_VERSION_4);
        let back = PackIndex::decode(&idx.encode()).unwrap();
        assert_eq!(back.version, IDX_VERSION_4);
        for e in &v4 {
            let b = back.entry(&e.id).unwrap();
            assert_eq!(b.meta, e.meta);
            assert_eq!(b.recipe, e.recipe);
        }
    }

    #[test]
    fn duplicate_ids_rejected() {
        let id = hash_bytes(b"dup");
        let entries = vec![
            IdxEntry { id, offset: 13, len: 4, meta: None, recipe: false },
            IdxEntry { id, offset: 30, len: 4, meta: None, recipe: false },
        ];
        assert!(PackIndex::from_entries(entries, [0u8; 32]).is_err());
    }

    #[test]
    fn corruption_detected() {
        let dir = tmp_dir("corrupt");
        let mut w = PackWriter::create(&dir).unwrap();
        let id = hash_bytes(b"x");
        w.add(id, b"payload-bytes").unwrap();
        let pack = w.finish().unwrap();
        pack.verify().unwrap();
        // Flip one payload byte.
        let mut bytes = std::fs::read(&pack.path).unwrap();
        bytes[(header_len(VERSION) + 8) as usize] ^= 0xff;
        std::fs::write(&pack.path, &bytes).unwrap();
        let reopened = PackFile::open(&pack.path).unwrap();
        assert!(reopened.verify().is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn lying_index_metadata_detected() {
        use crate::store::format::TensorObject;
        use crate::tensor::DType;

        let dir = tmp_dir("lying-meta");
        let mut w = PackWriter::create(&dir).unwrap();
        let obj = TensorObject::Raw {
            dtype: DType::F32,
            shape: vec![2],
            payload: vec![0u8; 8],
        };
        let id = hash_bytes(b"raw-obj");
        w.add(id, &obj.encode()).unwrap();
        let pack = w.finish().unwrap();
        pack.verify().unwrap();
        // Rewrite the index claiming the object is a delta: verify must
        // catch metadata that contradicts the stored object header.
        let mut entries = pack.index.entries.clone();
        entries[0].meta = Some(EntryMeta {
            kind: ObjectKind::Delta,
            parent: Some(hash_bytes(b"bogus-parent")),
            depth: 3,
            numel: Some(2),
        });
        let lying = PackIndex::from_entries(entries, pack.index.pack_sha).unwrap();
        lying.save(&PackFile::idx_path(&pack.path)).unwrap();
        let reopened = PackFile::open(&pack.path).unwrap();
        let err = reopened.verify().unwrap_err().to_string();
        assert!(err.contains("metadata mismatch"), "got: {err}");

        // A lying numel (kind/parent correct) must be caught too.
        let mut entries = pack.index.entries.clone();
        let good = entries[0].meta.unwrap();
        entries[0].meta = Some(EntryMeta { numel: Some(999), ..good });
        let lying = PackIndex::from_entries(entries, pack.index.pack_sha).unwrap();
        lying.save(&PackFile::idx_path(&pack.path)).unwrap();
        let reopened = PackFile::open(&pack.path).unwrap();
        let err = reopened.verify().unwrap_err().to_string();
        assert!(err.contains("numel mismatch"), "got: {err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn abort_leaves_no_pack() {
        let dir = tmp_dir("abort");
        let mut w = PackWriter::create(&dir).unwrap();
        w.add(hash_bytes(b"y"), b"yy").unwrap();
        w.abort().unwrap();
        let left: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert!(left.is_empty(), "abort must remove the temp pack");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Deterministic pseudo-random bytes (chunk boundaries need entropy).
    fn noise(n: usize, mut seed: u64) -> Vec<u8> {
        let mut out = Vec::with_capacity(n + 8);
        while out.len() < n {
            seed = seed
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            out.extend_from_slice(&seed.to_le_bytes());
        }
        out.truncate(n);
        out
    }

    #[test]
    fn chunked_pack_dedups_shared_regions_bit_exactly() {
        let shared = noise(16 * 1024, 42);
        let blobs: Vec<Vec<u8>> = (0..4u64)
            .map(|i| {
                let mut b = shared.clone();
                b.extend_from_slice(&noise(1024, 1000 + i));
                b
            })
            .collect();
        let ids: Vec<ObjectId> = blobs.iter().map(|b| hash_bytes(b)).collect();

        // Baseline: the same objects in a plain v2 pack.
        let dir = tmp_dir("chunked-baseline");
        let mut w = PackWriter::create(&dir).unwrap();
        for (id, b) in ids.iter().zip(&blobs) {
            w.add(*id, b).unwrap();
        }
        let plain = w.finish().unwrap();
        assert_eq!(plain.version, VERSION);

        // Chunk-dedup writer: later blobs become recipes over the first.
        let cdir = tmp_dir("chunked");
        let mut w = PackWriter::create_chunked(&cdir, PackFraming::Raw).unwrap();
        for (id, b) in ids.iter().zip(&blobs) {
            w.add(*id, b).unwrap();
        }
        let (shared_chunks, bytes_saved, recipes) = w.dedup_stats();
        assert!(recipes >= 3, "expected ≥3 recipe entries, got {recipes}");
        assert!(shared_chunks > 0);
        assert!(bytes_saved as usize > 3 * 12 * 1024, "saved only {bytes_saved}");
        let pack = w.finish().unwrap();
        assert_eq!(pack.version, VERSION_CHUNKED);
        assert_eq!(pack.index.version, IDX_VERSION_4);
        assert!(
            pack.size_bytes() < plain.size_bytes() / 2,
            "dedup pack {} vs plain {}",
            pack.size_bytes(),
            plain.size_bytes()
        );

        // Reads are bit-exact, through the live handle and a reopen, and
        // structural verification understands recipes.
        pack.verify().unwrap();
        for (id, b) in ids.iter().zip(&blobs) {
            assert_eq!(pack.get(id).unwrap().unwrap(), *b);
        }
        let reopened = PackFile::open(&pack.path).unwrap();
        reopened.verify().unwrap();
        for (id, b) in ids.iter().zip(&blobs) {
            assert_eq!(reopened.get(id).unwrap().unwrap(), *b);
        }
        std::fs::remove_dir_all(&dir).unwrap();
        std::fs::remove_dir_all(&cdir).unwrap();
    }

    #[test]
    fn chunked_pack_without_repeats_still_reads() {
        // No shared content → no recipes → index stays v3, pack is v3.
        let dir = tmp_dir("chunked-norepeat");
        let mut w = PackWriter::create_chunked(&dir, PackFraming::Raw).unwrap();
        let blobs: Vec<Vec<u8>> = (0..5u64).map(|i| noise(2048, 7000 + i)).collect();
        let ids: Vec<ObjectId> = blobs.iter().map(|b| hash_bytes(b)).collect();
        for (id, b) in ids.iter().zip(&blobs) {
            w.add(*id, b).unwrap();
        }
        assert_eq!(w.dedup_stats(), (0, 0, 0));
        let pack = w.finish().unwrap();
        assert_eq!(pack.version, VERSION_CHUNKED);
        assert_eq!(pack.index.version, IDX_VERSION);
        pack.verify().unwrap();
        for (id, b) in ids.iter().zip(&blobs) {
            assert_eq!(pack.get(id).unwrap().unwrap(), *b);
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
