//! Lock-free random-access readers over sealed pack files.
//!
//! PR 1 guarded every pack read with a `Mutex<File>` around `seek` +
//! `read_exact`, which serialized all readers of a pack — exactly the
//! wrong shape for the request-serving layer, where many threads cold-
//! materialize checkpoints from the same shared packs. Packs are sealed
//! and immutable once renamed to their content hash, so concurrent reads
//! need no coordination at all; what was missing is a positionless read
//! primitive. [`PackMmap`] provides one, picked at compile time:
//!
//! * **mmap** (`unix` + the default `mmap` feature) — the pack is
//!   memory-mapped read-only once at open; a read is a bounds-checked
//!   `memcpy` out of the mapping and the OS page cache is shared across
//!   every open handle of the same pack.
//! * **pread** (`unix` without the `mmap` feature) — positional
//!   `read_exact_at` on a shared file descriptor; the kernel offset is
//!   per-call, so readers never contend.
//! * **locked** (non-unix) — the portable last resort: `seek` +
//!   `read_exact` behind a mutex, i.e. the pre-concurrent behaviour.
//!
//! All three expose the same API and all three are `Send + Sync`, which
//! is what lets [`super::PackFile`], `PackedStore` and the `Store` façade
//! be shared freely across threads.

use std::fs::File;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// A sealed pack's bytes, readable at arbitrary offsets without locking
/// (memory-mapped by default; see the module docs for the fallbacks).
///
/// A pack with outer zstd framing cannot be served straight from the
/// file — its logical byte image only exists after decompression — so a
/// second backing exists: an owned in-memory buffer
/// ([`PackMmap::from_owned`], reader kind `"owned"`), which is just as
/// lock-free (shared immutable reads).
pub struct PackMmap {
    backing: Backing,
    len: u64,
}

enum Backing {
    File(imp::Reader),
    Owned(Vec<u8>),
}

impl PackMmap {
    /// Open `path` for lock-free random-access reads.
    pub fn open(path: &Path) -> Result<PackMmap> {
        let file = File::open(path)
            .with_context(|| format!("opening pack {}", path.display()))?;
        let len = file
            .metadata()
            .with_context(|| format!("stat pack {}", path.display()))?
            .len();
        let imp = imp::Reader::new(file, len)
            .with_context(|| format!("mapping pack {}", path.display()))?;
        Ok(PackMmap { backing: Backing::File(imp), len })
    }

    /// Serve reads from an owned buffer (the decoded logical image of a
    /// zstd-framed pack).
    pub fn from_owned(bytes: Vec<u8>) -> PackMmap {
        let len = bytes.len() as u64;
        PackMmap { backing: Backing::Owned(bytes), len }
    }

    /// Total length in bytes (file length, or owned-buffer length).
    pub fn len(&self) -> u64 {
        self.len
    }

    /// `true` for a zero-length file (never the case for a sealed pack).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Which read strategy backs this handle: `"mmap"`, `"pread"`,
    /// `"locked"`, or `"owned"` (decoded zstd-framed pack).
    pub fn kind(&self) -> &'static str {
        match &self.backing {
            Backing::File(_) => imp::KIND,
            Backing::Owned(_) => "owned",
        }
    }

    /// Read exactly `len` bytes starting at `offset`. Bounds are checked
    /// against the total length before the backend is consulted.
    pub fn read_at(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
        let end = offset
            .checked_add(len as u64)
            .ok_or_else(|| anyhow::anyhow!("pack read range overflows"))?;
        if end > self.len {
            bail!(
                "pack read out of bounds: offset {offset} + len {len} > file size {}",
                self.len
            );
        }
        if len == 0 {
            // Never reaches a backend: the mmap reader's pointer may be
            // null for a zero-length file, and even an empty slice must
            // not be built from a null pointer.
            return Ok(Vec::new());
        }
        match &self.backing {
            Backing::File(imp) => imp.read_at(offset, len),
            Backing::Owned(buf) => {
                Ok(buf[offset as usize..offset as usize + len].to_vec())
            }
        }
    }
}

#[cfg(all(unix, feature = "mmap"))]
mod imp {
    use std::fs::File;
    use std::os::unix::io::AsRawFd;

    use anyhow::Result;

    pub const KIND: &str = "mmap";

    /// Read-only `mmap(2)` of the whole pack. The mapping outlives the
    /// file descriptor, so the `File` is dropped after mapping.
    pub struct Reader {
        ptr: *mut libc::c_void,
        len: usize,
    }

    // SAFETY: the mapping is PROT_READ over a sealed, immutable file and
    // the raw pointer is only ever read through `read_at`'s bounds-checked
    // copies; no interior mutation exists to race on.
    unsafe impl Send for Reader {}
    unsafe impl Sync for Reader {}

    impl Reader {
        pub fn new(file: File, len: u64) -> Result<Reader> {
            // Explicit conversion: on 32-bit targets a >4 GiB pack must
            // fail loudly, not silently map a truncated prefix.
            let len = usize::try_from(len)
                .map_err(|_| anyhow::anyhow!("pack too large to mmap on this platform"))?;
            if len == 0 {
                // mmap(2) rejects zero-length maps; a null reader is fine
                // because PackMmap bounds-checks every read first.
                return Ok(Reader { ptr: std::ptr::null_mut(), len: 0 });
            }
            let ptr = unsafe {
                libc::mmap(
                    std::ptr::null_mut(),
                    len,
                    libc::PROT_READ,
                    libc::MAP_SHARED,
                    file.as_raw_fd(),
                    0,
                )
            };
            if ptr == libc::MAP_FAILED {
                return Err(anyhow::anyhow!(
                    "mmap failed: {}",
                    std::io::Error::last_os_error()
                ));
            }
            Ok(Reader { ptr, len })
        }

        pub fn read_at(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
            // Caller (PackMmap::read_at) has bounds-checked offset + len.
            let src = unsafe {
                std::slice::from_raw_parts(
                    (self.ptr as *const u8).add(offset as usize),
                    len,
                )
            };
            Ok(src.to_vec())
        }
    }

    impl Drop for Reader {
        fn drop(&mut self) {
            if !self.ptr.is_null() {
                unsafe {
                    libc::munmap(self.ptr, self.len);
                }
            }
        }
    }
}

#[cfg(all(unix, not(feature = "mmap")))]
mod imp {
    use std::fs::File;
    use std::os::unix::fs::FileExt;

    use anyhow::{Context, Result};

    pub const KIND: &str = "pread";

    /// Positional reads (`pread(2)`): the offset travels with each call,
    /// so a single shared descriptor serves any number of threads.
    pub struct Reader {
        file: File,
    }

    impl Reader {
        pub fn new(file: File, _len: u64) -> Result<Reader> {
            Ok(Reader { file })
        }

        pub fn read_at(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
            let mut buf = vec![0u8; len];
            self.file
                .read_exact_at(&mut buf, offset)
                .context("short positional read in pack")?;
            Ok(buf)
        }
    }
}

#[cfg(not(unix))]
mod imp {
    use std::fs::File;
    use std::io::{Read, Seek, SeekFrom};
    use std::sync::Mutex;

    use anyhow::{Context, Result};

    pub const KIND: &str = "locked";

    /// Portable fallback: seek + read behind a mutex (serialized reads,
    /// the pre-concurrent behaviour).
    pub struct Reader {
        file: Mutex<File>,
    }

    impl Reader {
        pub fn new(file: File, _len: u64) -> Result<Reader> {
            Ok(Reader { file: Mutex::new(file) })
        }

        pub fn read_at(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
            let mut f = self.file.lock().unwrap();
            f.seek(SeekFrom::Start(offset))?;
            let mut buf = vec![0u8; len];
            f.read_exact(&mut buf).context("short read in pack")?;
            Ok(buf)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_at_roundtrip_and_bounds() {
        let dir = std::env::temp_dir()
            .join(format!("mgit-mmap-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("data.bin");
        let payload: Vec<u8> = (0..=255u8).collect();
        std::fs::write(&path, &payload).unwrap();

        let m = PackMmap::open(&path).unwrap();
        assert_eq!(m.len(), 256);
        assert!(!m.is_empty());
        assert_eq!(m.read_at(0, 4).unwrap(), &payload[..4]);
        assert_eq!(m.read_at(100, 56).unwrap(), &payload[100..156]);
        assert_eq!(m.read_at(255, 1).unwrap(), &payload[255..]);
        assert_eq!(m.read_at(256, 0).unwrap(), Vec::<u8>::new());
        assert!(m.read_at(250, 7).is_err(), "read past EOF must fail");
        assert!(m.read_at(u64::MAX, 2).is_err(), "overflow must fail");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn concurrent_readers_see_identical_bytes() {
        let dir = std::env::temp_dir()
            .join(format!("mgit-mmap-conc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("data.bin");
        let payload: Vec<u8> = (0..4096u32).flat_map(|i| i.to_le_bytes()).collect();
        std::fs::write(&path, &payload).unwrap();

        let m = PackMmap::open(&path).unwrap();
        run_concurrent(&m, &payload);
        let o = PackMmap::from_owned(payload.clone());
        assert_eq!(o.kind(), "owned");
        run_concurrent(&o, &payload);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    fn run_concurrent(m: &PackMmap, payload: &[u8]) {
        std::thread::scope(|s| {
            for t in 0..4 {
                s.spawn(move || {
                    for i in 0..200usize {
                        let off = ((t * 997 + i * 131) % 4000) * 4;
                        let got = m.read_at(off as u64, 64).unwrap();
                        assert_eq!(&got[..], &payload[off..off + 64]);
                    }
                });
            }
        });
    }
}
