//! `MGCR` chunk-ref recipes: the pack v3 entry payload that stores an
//! object as a copy/literal program over earlier bytes of the same
//! pack.
//!
//! When the chunk-dedup writer ([`super::PackWriter`], `repack
//! --similarity`) sees an object whose content-defined chunks
//! ([`crate::delta::chunk`]) already exist earlier in the pack being
//! written, it stores a recipe instead of the bytes: shared ranges
//! become `copy` ops referencing the pack's *logical* image, novel
//! ranges become inline `literal` ops. Reassembly
//! ([`Recipe::reassemble`]) is a single forward pass — every copy
//! source lies strictly before the recipe's own entry, so there is no
//! recursion and no cycle — and reproduces the original object bytes
//! exactly.
//!
//! On-disk layout (little-endian), stored where an inline object would
//! be, behind the usual `len u64` entry prefix:
//!
//! ```text
//! magic "MGCR"                  4 bytes
//! ulen  u64                     reconstructed object byte length
//! nops  u32                     number of ops
//! ops nops ×:
//!     kind u8                   0 = copy, 1 = literal
//!     -- copy --
//!     src  u64                  logical offset in this pack's image
//!     len  u32
//!     -- literal --
//!     len  u32
//!     bytes [len]
//! ```

use anyhow::{bail, Result};

use super::ByteReader;

/// Recipe payload magic.
pub const RECIPE_MAGIC: &[u8; 4] = b"MGCR";

/// One step of a recipe program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RecipeOp {
    /// Copy `len` bytes from logical offset `src` of the same pack.
    Copy { src: u64, len: u32 },
    /// Append these bytes verbatim.
    Literal(Vec<u8>),
}

/// A decoded chunk-ref recipe: the reconstructed length plus the op
/// program that produces it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Recipe {
    /// Exact byte length of the reconstructed object.
    pub ulen: u64,
    pub ops: Vec<RecipeOp>,
}

/// Serialized size of the fixed recipe header (magic + ulen + nops).
pub const HEADER_LEN: usize = 4 + 8 + 4;
/// Serialized size of one copy op (kind + src + len).
pub const COPY_OP_LEN: usize = 1 + 8 + 4;
/// Serialized overhead of one literal op before its data (kind + len).
pub const LITERAL_OP_OVERHEAD: usize = 1 + 4;

impl Recipe {
    /// Quick sniff: do these stored bytes look like a recipe?
    pub fn is_recipe(bytes: &[u8]) -> bool {
        bytes.len() >= 4 && &bytes[..4] == RECIPE_MAGIC
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_len());
        out.extend_from_slice(RECIPE_MAGIC);
        out.extend_from_slice(&self.ulen.to_le_bytes());
        out.extend_from_slice(&(self.ops.len() as u32).to_le_bytes());
        for op in &self.ops {
            match op {
                RecipeOp::Copy { src, len } => {
                    out.push(0);
                    out.extend_from_slice(&src.to_le_bytes());
                    out.extend_from_slice(&len.to_le_bytes());
                }
                RecipeOp::Literal(data) => {
                    out.push(1);
                    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
                    out.extend_from_slice(data);
                }
            }
        }
        out
    }

    /// Exact serialized length of [`Recipe::encode`]'s output, without
    /// materializing it — the writer uses this to decide whether a
    /// recipe actually saves bytes before committing to one.
    pub fn encoded_len(&self) -> usize {
        HEADER_LEN
            + self
                .ops
                .iter()
                .map(|op| match op {
                    RecipeOp::Copy { .. } => COPY_OP_LEN,
                    RecipeOp::Literal(d) => LITERAL_OP_OVERHEAD + d.len(),
                })
                .sum::<usize>()
    }

    pub fn decode(bytes: &[u8]) -> Result<Recipe> {
        let mut r = ByteReader { b: bytes, pos: 0 };
        if r.take(4)? != RECIPE_MAGIC {
            bail!("not an MGCR chunk recipe");
        }
        let ulen = r.u64()?;
        let nops = r.u32()? as usize;
        let mut ops = Vec::with_capacity(nops.min(1024));
        for _ in 0..nops {
            match r.u8()? {
                0 => {
                    let src = r.u64()?;
                    let len = r.u32()?;
                    ops.push(RecipeOp::Copy { src, len });
                }
                1 => {
                    let len = r.u32()? as usize;
                    ops.push(RecipeOp::Literal(r.take(len)?.to_vec()));
                }
                other => bail!("unknown recipe op kind {other}"),
            }
        }
        if r.pos != bytes.len() {
            bail!("trailing bytes in chunk recipe");
        }
        Ok(Recipe { ulen, ops })
    }

    /// The (src, len) pair of every copy op, for bounds validation.
    pub fn copy_ranges(&self) -> impl Iterator<Item = (u64, u64)> + '_ {
        self.ops.iter().filter_map(|op| match op {
            RecipeOp::Copy { src, len } => Some((*src, *len as u64)),
            RecipeOp::Literal(_) => None,
        })
    }

    /// Run the program: `read` serves copy ops from the pack's logical
    /// image. Fails if the output length disagrees with `ulen` — a
    /// recipe must reproduce its object exactly or not at all.
    pub fn reassemble(
        &self,
        read: impl Fn(u64, usize) -> Result<Vec<u8>>,
    ) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(self.ulen as usize);
        for op in &self.ops {
            match op {
                RecipeOp::Copy { src, len } => {
                    out.extend_from_slice(&read(*src, *len as usize)?);
                }
                RecipeOp::Literal(data) => out.extend_from_slice(data),
            }
        }
        if out.len() as u64 != self.ulen {
            bail!(
                "chunk recipe reassembled to {} bytes, header says {}",
                out.len(),
                self.ulen
            );
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Recipe {
        Recipe {
            ulen: 10,
            ops: vec![
                RecipeOp::Copy { src: 100, len: 4 },
                RecipeOp::Literal(vec![9, 8, 7]),
                RecipeOp::Copy { src: 200, len: 3 },
            ],
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let r = sample();
        let bytes = r.encode();
        assert_eq!(bytes.len(), r.encoded_len());
        assert!(Recipe::is_recipe(&bytes));
        assert_eq!(Recipe::decode(&bytes).unwrap(), r);
    }

    #[test]
    fn reassemble_runs_the_program() {
        let r = sample();
        let out = r
            .reassemble(|src, len| {
                // Pretend the logical image holds `src % 256` repeated.
                Ok(vec![(src % 256) as u8; len])
            })
            .unwrap();
        assert_eq!(out, vec![100, 100, 100, 100, 9, 8, 7, 200, 200, 200]);
    }

    #[test]
    fn length_mismatch_rejected() {
        let mut r = sample();
        r.ulen = 11;
        assert!(r.reassemble(|src, len| Ok(vec![(src % 256) as u8; len])).is_err());
    }

    #[test]
    fn truncation_and_garbage_rejected() {
        let bytes = sample().encode();
        assert!(Recipe::decode(&bytes[..bytes.len() - 1]).is_err());
        assert!(Recipe::decode(b"NOPE").is_err());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(Recipe::decode(&trailing).is_err());
        let mut bad_op = bytes;
        bad_op[HEADER_LEN] = 7; // first op kind
        assert!(Recipe::decode(&bad_op).is_err());
    }

    #[test]
    fn copy_ranges_lists_only_copies() {
        let r = sample();
        let ranges: Vec<_> = r.copy_ranges().collect();
        assert_eq!(ranges, vec![(100, 4), (200, 3)]);
    }
}
