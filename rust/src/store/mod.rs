//! Content-addressed object store (§4, "content-based hashing").
//!
//! Every parameter tensor in every model of a lineage graph is stored at
//! most once, keyed by the SHA-256 of its *logical content* (dtype, shape,
//! raw values — matching the paper, which hashes tensor value and shape).
//! The stored payload for a key may be the raw tensor bytes or a
//! delta-compressed encoding against a parent tensor (see [`format`] and
//! the [`crate::delta`] pipeline) — the key always names the logical
//! content, so deduplication ("indirection") is automatic: a `put` of an
//! already-present key is a no-op dedup hit.
//!
//! Backends implement the [`ObjectStore`] trait:
//!
//! * [`MemStore`] — volatile map (benches, tests);
//! * [`DiskStore`] — loose objects, one file per object in a git-like
//!   fan-out (`.mgit/objects/aa/…`);
//! * [`PackedStore`] — loose staging directory + any number of
//!   append-only [`pack`] files with binary-searchable indexes. Lookups
//!   are loose-first, then across packs newest-first (duplicate ids
//!   across packs are value-identical by content addressing); writes
//!   always land loose (packs are produced by [`pack::repack()`],
//!   incrementally by default, so a long-lived store accumulates
//!   generations of packs);
//! * [`remote::RemoteStore`] — a remote origin (`mgit serve`) reached
//!   over HTTP/1.1 with a dependency-free blocking client;
//! * [`tiered::TieredStore`] — a hot local [`PackedStore`] layered over
//!   a cold [`remote::RemoteStore`], with read-through fill, a byte
//!   budget with LRU eviction of fills, negative-lookup caching and
//!   delta-parent prefetch. Selected by [`Store::open_tiered`] when
//!   `.mgit/remote` is configured.
//!
//! The [`Store`] façade wraps one backend behind a stable API so the
//! `lineage`, `delta`, `checkpoint` and `workloads` layers are
//! backend-agnostic. Mark-and-sweep GC walks caller-provided roots with a
//! caller-provided reference extractor (the store itself is
//! payload-agnostic); delta-parent references are strong: GC *aborts*
//! rather than sweep when a live object is unreadable, because sweeping
//! around a missing mid-chain object would corrupt every chain below it.
//!
//! ## Thread safety
//!
//! [`ObjectStore`] requires `Send + Sync`, and every backend — and the
//! [`Store`] façade itself — satisfies it, so one store handle can be
//! shared by reference across reader threads (chain reconstruction fans
//! out in [`crate::delta::load_parallel`]):
//!
//! * [`MemStore`] serializes through an internal mutex;
//! * [`DiskStore`] holds no mutable state (the filesystem coordinates);
//! * [`PackedStore`] reads packs lock-free via memory-mapped
//!   [`pack::PackMmap`] readers — concurrent pack reads never contend.
//!
//! Writes are safe from any thread; loose writes are atomic (each `put`
//! stages to a private temp file, then renames), so readers never see a
//! partial object and concurrent `put`s of the same id are
//! content-idempotent — in a rare tie both racers may report "newly
//! written" (overcounting the byte counters slightly) but the stored
//! bytes are identical either way. Mutating the *pack set* (repack/GC)
//! takes `&mut` and therefore still requires exclusive ownership.

pub mod format;
pub mod pack;
pub mod remote;
pub mod tiered;
pub mod wal;

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};
use sha2::{Digest, Sha256};

// Process-global read telemetry (served by `GET /metrics`). Lazily
// resolved statics: after first touch each event is a single relaxed
// atomic add — the loose and pack read paths stay lock-free.
static OBS_LOOSE_READS: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("store.loose_reads");
static OBS_PACK_READS: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("store.pack_reads");
static OBS_READ_BYTES: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("store.read_bytes");

/// SHA-256 content id.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ObjectId(pub [u8; 32]);

impl ObjectId {
    /// Full 64-char lowercase hex form.
    pub fn hex(&self) -> String {
        self.0.iter().map(|b| format!("{b:02x}")).collect()
    }

    /// Abbreviated 12-char hex form (log/error messages).
    pub fn short(&self) -> String {
        self.hex()[..12].to_string()
    }

    /// Parse the full 64-char hex form back into an id.
    pub fn from_hex(s: &str) -> Result<ObjectId> {
        if s.len() != 64 {
            bail!("object id must be 64 hex chars, got {}", s.len());
        }
        let mut out = [0u8; 32];
        for (i, byte) in out.iter_mut().enumerate() {
            *byte = u8::from_str_radix(&s[2 * i..2 * i + 2], 16)
                .map_err(|_| anyhow!("bad hex in object id"))?;
        }
        Ok(ObjectId(out))
    }
}

impl fmt::Debug for ObjectId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ObjectId({})", self.short())
    }
}

impl fmt::Display for ObjectId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.hex())
    }
}

/// Hash arbitrary bytes.
pub fn hash_bytes(bytes: &[u8]) -> ObjectId {
    let mut h = Sha256::new();
    h.update(bytes);
    ObjectId(h.finalize().into())
}

/// Hash a logical tensor: dtype code, dims, then the raw payload.
pub fn hash_tensor(dtype: crate::tensor::DType, shape: &[usize], payload: &[u8]) -> ObjectId {
    let mut h = Sha256::new();
    h.update([dtype.code(), shape.len() as u8]);
    for d in shape {
        h.update((*d as u64).to_le_bytes());
    }
    h.update(payload);
    ObjectId(h.finalize().into())
}

// ---------------------------------------------------------------------------
// The backend trait
// ---------------------------------------------------------------------------

/// Uniform object-storage interface implemented by every backend.
///
/// Ids name *logical* content; `put` of an existing id is a dedup no-op.
/// The `Send + Sync` bound is part of the contract: any backend must be
/// shareable by reference across threads (see the module docs).
pub trait ObjectStore: Send + Sync {
    /// Fetch the payload stored under `id` (error if absent).
    fn get(&self, id: &ObjectId) -> Result<Vec<u8>>;
    /// Store `bytes` under `id`; `true` if newly written, `false` on a
    /// dedup hit.
    fn put(&self, id: ObjectId, bytes: &[u8]) -> Result<bool>;
    /// Whether `id` is present (loose or packed).
    fn contains(&self, id: &ObjectId) -> bool;
    /// Every object id in the store (deduplicated across locations).
    fn list(&self) -> Result<Vec<ObjectId>>;
    /// Number of distinct objects.
    fn len(&self) -> Result<usize> {
        Ok(self.list()?.len())
    }
    /// Remove the mutable copy of `id` if one exists; `true` if something
    /// was deleted. Backends with immutable segments (packs) return
    /// `false` for ids that only live there — compaction reclaims those.
    fn remove(&self, id: &ObjectId) -> Result<bool>;
    /// Total stored payload bytes (the numerator of compression ratios).
    fn stored_bytes(&self) -> Result<u64>;
}

// ---------------------------------------------------------------------------
// MemStore
// ---------------------------------------------------------------------------

/// Volatile in-memory backend (tests, benches).
#[derive(Default)]
pub struct MemStore {
    map: Mutex<HashMap<ObjectId, Vec<u8>>>,
}

impl MemStore {
    pub fn new() -> MemStore {
        MemStore::default()
    }
}

impl ObjectStore for MemStore {
    fn get(&self, id: &ObjectId) -> Result<Vec<u8>> {
        self.map
            .lock()
            .unwrap()
            .get(id)
            .cloned()
            .ok_or_else(|| anyhow!("object {} not found", id.short()))
    }

    fn put(&self, id: ObjectId, bytes: &[u8]) -> Result<bool> {
        let mut map = self.map.lock().unwrap();
        if map.contains_key(&id) {
            return Ok(false);
        }
        map.insert(id, bytes.to_vec());
        Ok(true)
    }

    fn contains(&self, id: &ObjectId) -> bool {
        self.map.lock().unwrap().contains_key(id)
    }

    fn list(&self) -> Result<Vec<ObjectId>> {
        Ok(self.map.lock().unwrap().keys().copied().collect())
    }

    fn remove(&self, id: &ObjectId) -> Result<bool> {
        Ok(self.map.lock().unwrap().remove(id).is_some())
    }

    fn stored_bytes(&self) -> Result<u64> {
        Ok(self.map.lock().unwrap().values().map(|v| v.len() as u64).sum())
    }
}

// ---------------------------------------------------------------------------
// DiskStore (loose objects)
// ---------------------------------------------------------------------------

/// Loose on-disk backend: one file per object under a two-hex-char
/// fan-out directory (`root/aa/bbbb…`). The reserved `root/pack/`
/// subdirectory (used by [`PackedStore`]) is ignored here.
pub struct DiskStore {
    root: PathBuf,
}

impl DiskStore {
    /// Open (creating if needed) a loose store rooted at `dir`. Stale
    /// `*.tmp*` staging files from puts that crashed mid-write are swept
    /// here — but only past a grace period, because *another process*
    /// may have an in-flight put staged (open-before-threads only rules
    /// out this process's own writers).
    pub fn open(dir: &Path) -> Result<DiskStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating object store at {}", dir.display()))?;
        let store = DiskStore { root: dir.to_path_buf() };
        store.sweep_stale_tmp();
        Ok(store)
    }

    /// Best-effort removal of orphaned put-staging files (crash debris).
    /// Only files older than a grace period are swept: another live mgit
    /// process may be between its staging write and the rename, and
    /// deleting its tmp file would fail that in-flight `put`.
    fn sweep_stale_tmp(&self) {
        const GRACE: std::time::Duration = std::time::Duration::from_secs(15 * 60);
        let now = std::time::SystemTime::now();
        let Ok(fans) = std::fs::read_dir(&self.root) else { return };
        for fan in fans.filter_map(|e| e.ok()) {
            let name = fan.file_name().to_string_lossy().to_string();
            if name.len() != 2 || !fan.path().is_dir() {
                continue; // reserved dirs ("pack"), strays
            }
            let Ok(objs) = std::fs::read_dir(fan.path()) else { continue };
            for obj in objs.filter_map(|e| e.ok()) {
                if !obj.file_name().to_string_lossy().contains(".tmp") {
                    continue;
                }
                let stale = obj
                    .metadata()
                    .and_then(|m| m.modified())
                    .ok()
                    .and_then(|t| now.duration_since(t).ok())
                    .map(|age| age > GRACE)
                    .unwrap_or(false);
                if stale {
                    let _ = std::fs::remove_file(obj.path());
                }
            }
        }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn path_for(&self, id: &ObjectId) -> PathBuf {
        let hex = id.hex();
        self.root.join(&hex[..2]).join(&hex[2..])
    }
}

impl ObjectStore for DiskStore {
    fn get(&self, id: &ObjectId) -> Result<Vec<u8>> {
        let bytes = std::fs::read(self.path_for(id))
            .with_context(|| format!("object {} not found", id.short()))?;
        OBS_LOOSE_READS.inc();
        OBS_READ_BYTES.add(bytes.len() as u64);
        Ok(bytes)
    }

    fn put(&self, id: ObjectId, bytes: &[u8]) -> Result<bool> {
        if self.contains(&id) {
            return Ok(false);
        }
        let path = self.path_for(&id);
        std::fs::create_dir_all(path.parent().unwrap())?;
        // Write-then-rename for atomicity. The temp name is unique per
        // call: two threads putting the same id concurrently must not
        // clobber each other's staging file (each rename is then an
        // atomic replace of identical content — a benign last-wins).
        static PUT_SEQ: AtomicU64 = AtomicU64::new(0);
        let seq = PUT_SEQ.fetch_add(1, Ordering::Relaxed);
        let tmp = path.with_extension(format!("tmp{}-{seq}", std::process::id()));
        std::fs::write(&tmp, bytes)?;
        std::fs::rename(&tmp, &path)?;
        Ok(true)
    }

    fn contains(&self, id: &ObjectId) -> bool {
        self.path_for(id).exists()
    }

    fn list(&self) -> Result<Vec<ObjectId>> {
        let mut out = Vec::new();
        if !self.root.exists() {
            return Ok(out);
        }
        for fan in std::fs::read_dir(&self.root)? {
            let fan = fan?;
            if !fan.file_type()?.is_dir() {
                continue;
            }
            let prefix = fan.file_name().to_string_lossy().to_string();
            if prefix.len() != 2 {
                continue; // reserved dirs ("pack"), strays
            }
            for obj in std::fs::read_dir(fan.path())? {
                let name = obj?.file_name().to_string_lossy().to_string();
                if name.contains(".tmp") {
                    continue; // in-flight staging files
                }
                if let Ok(id) = ObjectId::from_hex(&format!("{prefix}{name}")) {
                    out.push(id);
                }
            }
        }
        Ok(out)
    }

    fn remove(&self, id: &ObjectId) -> Result<bool> {
        let path = self.path_for(id);
        if path.exists() {
            std::fs::remove_file(path)?;
            return Ok(true);
        }
        Ok(false)
    }

    fn stored_bytes(&self) -> Result<u64> {
        let mut total = 0;
        for id in self.list()? {
            total += std::fs::metadata(self.path_for(&id))?.len();
        }
        Ok(total)
    }
}

// ---------------------------------------------------------------------------
// PackedStore (loose staging + pack files)
// ---------------------------------------------------------------------------

/// Loose-first backend with pack files: reads check the loose staging
/// area, then every pack index newest-first (on open, packs load in
/// deterministic content-hash filename order; incremental repacks append
/// newer generations — ids name identical logical content, so any copy
/// serves); writes always land loose. [`pack::repack()`] migrates loose
/// objects into packs. Reads are lock-free end to end: the loose path
/// is one `read(2)` and the pack path is a [`pack::PackMmap`] copy.
pub struct PackedStore {
    loose: DiskStore,
    packs: Vec<pack::PackFile>,
    root: PathBuf,
}

impl PackedStore {
    /// Open `dir` as a packed store, loading every `pack/*.pack` index.
    pub fn open(dir: &Path) -> Result<PackedStore> {
        let loose = DiskStore::open(dir)?;
        let pack_dir = dir.join("pack");
        let mut packs = Vec::new();
        if pack_dir.exists() {
            let mut paths: Vec<PathBuf> = std::fs::read_dir(&pack_dir)?
                .filter_map(|e| e.ok())
                .map(|e| e.path())
                .filter(|p| {
                    p.extension().map(|x| x == "pack").unwrap_or(false)
                        // Belt and braces: never load half-written packs.
                        && !p
                            .file_name()
                            .map(|n| n.to_string_lossy().starts_with("tmp-"))
                            .unwrap_or(true)
                })
                .collect();
            paths.sort();
            for p in paths {
                packs.push(
                    pack::PackFile::open(&p)
                        .with_context(|| format!("loading pack {}", p.display()))?,
                );
            }
        }
        Ok(PackedStore { loose, packs, root: dir.to_path_buf() })
    }

    /// Directory holding `*.pack` / `*.idx` files (`<root>/pack`).
    pub fn pack_dir(&self) -> PathBuf {
        self.root.join("pack")
    }

    /// The loose staging area underneath this store.
    pub fn loose(&self) -> &DiskStore {
        &self.loose
    }

    /// All loaded packs, oldest generation first.
    pub fn packs(&self) -> &[pack::PackFile] {
        &self.packs
    }

    /// (loose object count, packed object count). Objects staged loose
    /// *and* present in a pack count once, as packed.
    pub fn counts(&self) -> Result<(usize, usize)> {
        let mut packed: HashSet<ObjectId> = HashSet::new();
        for p in &self.packs {
            packed.extend(p.index.ids());
        }
        let loose = self
            .loose
            .list()?
            .into_iter()
            .filter(|id| !packed.contains(id))
            .count();
        Ok((loose, packed.len()))
    }

    /// Chain metadata for `id` straight from pack-index v2+ entries —
    /// zero object reads (v3 entries additionally carry tensor numel).
    /// Answers for the *newest* pack holding `id`
    /// (matching [`PackedStore::get`]'s precedence among packs); returns
    /// `None` when that pack's index is v1 (no metadata) or no pack
    /// holds the id. Callers wanting `get()`-equivalent metadata must
    /// check the loose staging area first — [`Store::object_meta`] does.
    pub fn indexed_meta(&self, id: &ObjectId) -> Option<format::ObjectMeta> {
        for p in self.packs.iter().rev() {
            if let Some(e) = p.index.entry(id) {
                return e
                    .meta
                    .map(|m| format::ObjectMeta::from_index(m.kind, m.parent, m.numel));
            }
        }
        None
    }

    pub(crate) fn replace_packs(&mut self, packs: Vec<pack::PackFile>) {
        self.packs = packs;
    }

    /// Append a freshly sealed pack as the newest generation (incremental
    /// repack); reads prefer newer packs, though any copy of an id is
    /// value-identical by content addressing.
    pub(crate) fn add_pack(&mut self, p: pack::PackFile) {
        self.packs.push(p);
    }
}

// Compile-time proof of the module-doc thread-safety claims: the façade
// and every backend must be shareable across reader threads.
#[allow(dead_code)]
fn _assert_store_types_send_sync() {
    fn check<T: Send + Sync>() {}
    check::<MemStore>();
    check::<DiskStore>();
    check::<PackedStore>();
    check::<remote::RemoteStore>();
    check::<tiered::TieredStore>();
    check::<Store>();
}

impl ObjectStore for PackedStore {
    fn get(&self, id: &ObjectId) -> Result<Vec<u8>> {
        if self.loose.contains(id) {
            return self.loose.get(id); // counted as a loose read there
        }
        for p in self.packs.iter().rev() {
            if let Some(bytes) = p.get(id)? {
                OBS_PACK_READS.inc();
                OBS_READ_BYTES.add(bytes.len() as u64);
                return Ok(bytes);
            }
        }
        bail!("object {} not found (loose or packed)", id.short())
    }

    fn put(&self, id: ObjectId, bytes: &[u8]) -> Result<bool> {
        if self.contains(&id) {
            return Ok(false);
        }
        self.loose.put(id, bytes)
    }

    fn contains(&self, id: &ObjectId) -> bool {
        self.loose.contains(id) || self.packs.iter().any(|p| p.contains(id))
    }

    fn list(&self) -> Result<Vec<ObjectId>> {
        let mut seen: HashSet<ObjectId> = self.loose.list()?.into_iter().collect();
        for p in &self.packs {
            seen.extend(p.index.ids());
        }
        Ok(seen.into_iter().collect())
    }

    fn remove(&self, id: &ObjectId) -> Result<bool> {
        // Only the loose copy is mutable; packed objects are reclaimed by
        // `repack --prune`.
        self.loose.remove(id)
    }

    fn stored_bytes(&self) -> Result<u64> {
        let mut total = 0u64;
        let mut packed: HashSet<ObjectId> = HashSet::new();
        for p in &self.packs {
            for e in &p.index.entries {
                if packed.insert(e.id) {
                    total += e.len;
                }
            }
        }
        for id in self.loose.list()? {
            if !packed.contains(&id) {
                total += std::fs::metadata(self.loose.path_for(&id))?.len();
            }
        }
        Ok(total)
    }
}

// ---------------------------------------------------------------------------
// Store façade
// ---------------------------------------------------------------------------

/// Cumulative store statistics (for the Table-4/ablation benches and
/// `mgit stats`; the CLI persists these across invocations).
#[derive(Debug, Default)]
pub struct StoreStats {
    pub puts: AtomicU64,
    pub dedup_hits: AtomicU64,
    pub bytes_written: AtomicU64,
}

impl StoreStats {
    /// Drain the counters (used when persisting cumulative stats).
    pub fn take(&self) -> (u64, u64, u64) {
        (
            self.puts.swap(0, Ordering::Relaxed),
            self.dedup_hits.swap(0, Ordering::Relaxed),
            self.bytes_written.swap(0, Ordering::Relaxed),
        )
    }
}

enum BackendImpl {
    Mem(MemStore),
    Disk(DiskStore),
    Packed(PackedStore),
    Tiered(tiered::TieredStore),
}

/// Backend-agnostic handle used by all higher layers.
///
/// `Store` is `Send + Sync`: share it by reference across reader threads
/// (see the module docs for the per-backend guarantees).
///
/// # Examples
///
/// ```
/// use mgit::store::Store;
///
/// let store = Store::in_memory();
/// let id = store.put_blob(b"tensor bytes").unwrap();
/// assert!(store.has(&id));
/// assert_eq!(store.get(&id).unwrap(), b"tensor bytes");
/// // A second put of identical content is a dedup hit, not a write.
/// assert!(!store.put(id, b"tensor bytes").unwrap());
/// ```
pub struct Store {
    backend: BackendImpl,
    /// In-process put/dedup/byte counters (drained by the CLI into
    /// `.mgit/stats.json`).
    pub stats: StoreStats,
}

impl Store {
    /// Open (creating if needed) a loose-only on-disk store at `dir`.
    pub fn open(dir: &Path) -> Result<Store> {
        Ok(Store {
            backend: BackendImpl::Disk(DiskStore::open(dir)?),
            stats: StoreStats::default(),
        })
    }

    /// Open (creating if needed) a pack-capable on-disk store at `dir`:
    /// loose staging plus every existing `pack/*.pack`.
    pub fn open_packed(dir: &Path) -> Result<Store> {
        Ok(Store {
            backend: BackendImpl::Packed(PackedStore::open(dir)?),
            stats: StoreStats::default(),
        })
    }

    /// Open (creating if needed) a tiered store: the hot tier is the
    /// ordinary pack-capable layout at `dir`, misses read through to
    /// `cfg`'s origin (see [`tiered::TieredStore`]). Chosen by
    /// `Repo::open` when `.mgit/remote` exists.
    pub fn open_tiered(dir: &Path, cfg: &remote::RemoteConfig) -> Result<Store> {
        Ok(Store {
            backend: BackendImpl::Tiered(tiered::TieredStore::open(dir, cfg)?),
            stats: StoreStats::default(),
        })
    }

    /// Volatile in-memory store (tests, benches).
    pub fn in_memory() -> Store {
        Store { backend: BackendImpl::Mem(MemStore::new()), stats: StoreStats::default() }
    }

    fn obj(&self) -> &dyn ObjectStore {
        match &self.backend {
            BackendImpl::Mem(s) => s,
            BackendImpl::Disk(s) => s,
            BackendImpl::Packed(s) => s,
            BackendImpl::Tiered(s) => s,
        }
    }

    /// The pack-capable local store, if this backend has one. For a
    /// tiered store this is the *hot* tier, so pack-level operations
    /// (stats, repack, fsck) work unchanged against tiered repos.
    pub fn as_packed(&self) -> Option<&PackedStore> {
        match &self.backend {
            BackendImpl::Packed(s) => Some(s),
            BackendImpl::Tiered(s) => Some(s.hot()),
            _ => None,
        }
    }

    pub(crate) fn as_packed_mut(&mut self) -> Option<&mut PackedStore> {
        match &mut self.backend {
            BackendImpl::Packed(s) => Some(s),
            BackendImpl::Tiered(s) => Some(s.hot_mut()),
            _ => None,
        }
    }

    /// The tiered backend, if this store reads through a remote origin.
    pub fn as_tiered(&self) -> Option<&tiered::TieredStore> {
        match &self.backend {
            BackendImpl::Tiered(s) => Some(s),
            _ => None,
        }
    }


    /// Store `bytes` under `id`. Returns `true` if newly written, `false`
    /// on a dedup hit (content already present).
    pub fn put(&self, id: ObjectId, bytes: &[u8]) -> Result<bool> {
        self.stats.puts.fetch_add(1, Ordering::Relaxed);
        let wrote = self.obj().put(id, bytes)?;
        if wrote {
            self.stats
                .bytes_written
                .fetch_add(bytes.len() as u64, Ordering::Relaxed);
        } else {
            self.stats.dedup_hits.fetch_add(1, Ordering::Relaxed);
        }
        Ok(wrote)
    }

    /// Put-through-WAL seam for the writable serving tier: record the
    /// object in `wal` *before* materializing it in the backend, so a
    /// crash between the two is recovered by replay. Dedup hits skip
    /// both the log record and the write (the object is already
    /// durable). The caller batches [`wal::Wal::sync`] — typically one
    /// fsync per commit, not per object.
    pub fn put_via_wal(
        &self,
        wal: &mut wal::Wal,
        id: ObjectId,
        bytes: &[u8],
    ) -> Result<bool> {
        if self.has(&id) {
            // Count the dedup hit exactly like a direct put would.
            return self.put(id, bytes);
        }
        wal.append(&wal::WalRecord::Put { id, bytes: bytes.to_vec() })?;
        self.put(id, bytes)
    }

    /// Convenience: hash bytes and store them under their own hash.
    pub fn put_blob(&self, bytes: &[u8]) -> Result<ObjectId> {
        let id = hash_bytes(bytes);
        self.put(id, bytes)?;
        Ok(id)
    }

    /// Fetch the payload stored under `id` (error if absent).
    pub fn get(&self, id: &ObjectId) -> Result<Vec<u8>> {
        self.obj().get(id)
    }

    /// Header-only metadata for `id`: kind, delta-parent pointer, and —
    /// when the object bytes had to be read anyway — dtype/shape.
    ///
    /// Objects sealed in v2 packs (and not shadowed by a loose staging
    /// copy) are answered straight from the pack index with **zero
    /// object reads**; everything else falls back to reading the object
    /// and parsing its header only (never a payload decode). This is
    /// what makes repack marking, `fsck`'s orphan scan and the
    /// chain-depth statistics metadata-walks instead of store scans.
    pub fn object_meta(&self, id: &ObjectId) -> Result<format::ObjectMeta> {
        let packed = match &self.backend {
            BackendImpl::Packed(ps) => Some(ps),
            BackendImpl::Tiered(ts) => Some(ts.hot()),
            _ => None,
        };
        if let Some(ps) = packed {
            if !ps.loose.contains(id) {
                if let Some(m) = ps.indexed_meta(id) {
                    return Ok(m);
                }
            }
        }
        Ok(format::TensorObject::decode_meta(&self.get(id)?))
    }

    /// Whether `id` is present in the backend.
    pub fn has(&self, id: &ObjectId) -> bool {
        self.obj().contains(id)
    }

    /// Remove the mutable copy of `id` if one exists (packed copies are
    /// immutable; see [`ObjectStore::remove`]).
    pub fn remove(&self, id: &ObjectId) -> Result<()> {
        self.obj().remove(id)?;
        Ok(())
    }

    /// Every object id in the store.
    pub fn list(&self) -> Result<Vec<ObjectId>> {
        self.obj().list()
    }

    /// Total stored payload bytes (the numerator of compression ratios).
    pub fn stored_bytes(&self) -> Result<u64> {
        self.obj().stored_bytes()
    }

    /// Mark-and-sweep GC: keep everything reachable from `roots` through
    /// `refs` (which extracts outgoing ObjectIds from an object's
    /// payload — delta-parent pointers are walked transitively, so a
    /// whole live chain is strong). Returns the ids that were swept.
    ///
    /// Aborts with an error (sweeping nothing) if any *live* object is
    /// unreadable: proceeding would drop the unreadable object's own
    /// parents and corrupt every chain hanging off them.
    pub fn gc(
        &self,
        roots: &[ObjectId],
        refs: impl Fn(&[u8]) -> Vec<ObjectId>,
    ) -> Result<Vec<ObjectId>> {
        let mut live: HashSet<ObjectId> = HashSet::new();
        let mut stack: Vec<ObjectId> = roots.to_vec();
        while let Some(id) = stack.pop() {
            if !live.insert(id) {
                continue;
            }
            let bytes = self.get(&id).with_context(|| {
                format!(
                    "gc: live object {} is unreadable; aborting before the sweep \
                     (sweeping around a missing chain object would corrupt live \
                     delta chains — run `mgit fsck`)",
                    id.short()
                )
            })?;
            for r in refs(&bytes) {
                if !live.contains(&r) {
                    stack.push(r);
                }
            }
        }
        let mut swept = Vec::new();
        for id in self.list()? {
            if !live.contains(&id) && self.obj().remove(&id)? {
                swept.push(id);
            }
        }
        Ok(swept)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::format::TensorObject;
    use crate::tensor::DType;

    #[test]
    fn hex_roundtrip() {
        let id = hash_bytes(b"hello");
        let back = ObjectId::from_hex(&id.hex()).unwrap();
        assert_eq!(id, back);
        assert!(ObjectId::from_hex("zz").is_err());
    }

    #[test]
    fn tensor_hash_depends_on_shape_and_dtype() {
        let payload = vec![0u8; 16];
        let a = hash_tensor(DType::F32, &[4], &payload);
        let b = hash_tensor(DType::F32, &[2, 2], &payload);
        let c = hash_tensor(DType::I32, &[4], &payload);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, hash_tensor(DType::F32, &[4], &payload));
    }

    fn exercise(store: &Store) {
        let id = store.put_blob(b"abc").unwrap();
        assert!(store.has(&id));
        assert_eq!(store.get(&id).unwrap(), b"abc");
        // dedup
        assert!(!store.put(id, b"abc").unwrap());
        assert_eq!(store.stats.dedup_hits.load(Ordering::Relaxed), 1);
        let id2 = store.put_blob(b"defg").unwrap();
        let mut listed = store.list().unwrap();
        listed.sort();
        let mut want = vec![id, id2];
        want.sort();
        assert_eq!(listed, want);
        assert_eq!(store.stored_bytes().unwrap(), 7);
        store.remove(&id).unwrap();
        assert!(!store.has(&id));
        assert!(store.get(&id).is_err());
    }

    #[test]
    fn memory_backend() {
        exercise(&Store::in_memory());
    }

    #[test]
    fn disk_backend() {
        let dir = std::env::temp_dir().join(format!("mgit-store-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        exercise(&Store::open(&dir).unwrap());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn packed_backend_facade() {
        let dir =
            std::env::temp_dir().join(format!("mgit-store-packed-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        exercise(&Store::open_packed(&dir).unwrap());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// ObjectStore-trait conformance, run against all three backends.
    fn conformance(s: &dyn ObjectStore) {
        let a = hash_bytes(b"conf-a");
        let b = hash_bytes(b"conf-b");
        assert!(!s.contains(&a));
        assert!(s.get(&a).is_err());
        assert!(s.put(a, b"conf-a").unwrap());
        assert!(!s.put(a, b"conf-a").unwrap()); // dedup
        assert!(s.put(b, b"conf-b!").unwrap());
        assert!(s.contains(&a) && s.contains(&b));
        assert_eq!(s.get(&b).unwrap(), b"conf-b!");
        let mut ids = s.list().unwrap();
        ids.sort();
        let mut want = vec![a, b];
        want.sort();
        assert_eq!(ids, want);
        assert_eq!(s.len().unwrap(), 2);
        assert_eq!(s.stored_bytes().unwrap(), 6 + 7);
        assert!(s.remove(&a).unwrap());
        assert!(!s.remove(&a).unwrap());
        assert!(!s.contains(&a));
        assert_eq!(s.len().unwrap(), 1);
    }

    #[test]
    fn object_store_trait_conformance_all_backends() {
        conformance(&MemStore::new());

        let base =
            std::env::temp_dir().join(format!("mgit-conformance-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        conformance(&DiskStore::open(&base.join("disk")).unwrap());
        conformance(&PackedStore::open(&base.join("packed")).unwrap());

        // PackedStore with an actual pack file behind it: packed objects
        // are visible through every read path, writes stage loose, and
        // remove only touches the staging copy.
        let pdir = base.join("with-pack");
        let packed_id = hash_bytes(b"packed-payload");
        {
            let ps = PackedStore::open(&pdir).unwrap();
            let mut w = pack::PackWriter::create(&ps.pack_dir()).unwrap();
            w.add(packed_id, b"packed-payload").unwrap();
            w.finish().unwrap();
        }
        let ps = PackedStore::open(&pdir).unwrap();
        assert!(ps.contains(&packed_id));
        assert_eq!(ps.get(&packed_id).unwrap(), b"packed-payload");
        assert!(!ps.put(packed_id, b"packed-payload").unwrap()); // dedup vs pack
        assert!(!ps.remove(&packed_id).unwrap()); // immutable in pack
        assert!(ps.contains(&packed_id));
        assert_eq!(ps.counts().unwrap(), (0, 1));
        let loose_id = hash_bytes(b"loose-payload");
        assert!(ps.put(loose_id, b"loose-payload").unwrap());
        assert_eq!(ps.counts().unwrap(), (1, 1));
        assert_eq!(ps.len().unwrap(), 2);
        std::fs::remove_dir_all(&base).unwrap();
    }

    #[test]
    fn gc_keeps_reachable_chain() {
        let store = Store::in_memory();
        // c <- b <- a (a references b, b references c) plus unreachable d.
        let c = store.put_blob(b"c-payload").unwrap();
        let b = store.put_blob(c.hex().as_bytes()).unwrap();
        let a = store.put_blob(b.hex().as_bytes()).unwrap();
        let d = store.put_blob(b"garbage").unwrap();
        let swept = store
            .gc(&[a], |bytes| {
                std::str::from_utf8(bytes)
                    .ok()
                    .and_then(|s| ObjectId::from_hex(s).ok())
                    .into_iter()
                    .collect()
            })
            .unwrap();
        assert_eq!(swept, vec![d]);
        assert!(store.has(&a) && store.has(&b) && store.has(&c));
        assert!(!store.has(&d));
    }

    /// Extract MGTF delta-parent references (what `Repo::gc` does).
    fn tensor_refs(bytes: &[u8]) -> Vec<ObjectId> {
        TensorObject::decode(bytes).map(|o| o.refs()).unwrap_or_default()
    }

    /// Build a 3-deep MGTF chain raw <- d1 <- d2 under made-up ids and
    /// return (raw, d1, d2).
    fn mgtf_chain(store: &Store) -> (ObjectId, ObjectId, ObjectId) {
        let raw_id = hash_bytes(b"chain-raw");
        let d1_id = hash_bytes(b"chain-d1");
        let d2_id = hash_bytes(b"chain-d2");
        let raw = TensorObject::Raw {
            dtype: DType::F32,
            shape: vec![2],
            payload: vec![0u8; 8],
        };
        let mk_delta = |parent: ObjectId| TensorObject::Delta {
            dtype: DType::F32,
            shape: vec![2],
            parent,
            eps: 1e-4,
            codec: 1,
            n_quant: 2,
            grid: false,
            payload: vec![1, 2, 3],
        };
        store.put(raw_id, &raw.encode()).unwrap();
        store.put(d1_id, &mk_delta(raw_id).encode()).unwrap();
        store.put(d2_id, &mk_delta(d1_id).encode()).unwrap();
        (raw_id, d1_id, d2_id)
    }

    /// `object_meta` answers from pack-index v2 metadata when the object
    /// is sealed (no byte read ⇒ no shape), and falls back to a
    /// header-only parse for loose objects (shape known).
    #[test]
    fn object_meta_index_first_with_loose_fallback() {
        use crate::store::format::ObjectKind;

        let dir =
            std::env::temp_dir().join(format!("mgit-objmeta-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = Store::open_packed(&dir).unwrap();
        let (raw_id, d1_id, d2_id) = mgtf_chain(&store);
        // All loose: metadata via header parse, shape present.
        let m = store.object_meta(&d1_id).unwrap();
        assert_eq!(m.kind, ObjectKind::Delta);
        assert_eq!(m.parent, Some(raw_id));
        assert!(!m.from_index);
        assert!(m.shape.is_some(), "loose fallback knows the shape");

        // Seal the chain into a pack, drop loose copies, reopen.
        {
            let ps = store.as_packed().unwrap();
            let mut w = pack::PackWriter::create(&ps.pack_dir()).unwrap();
            for id in [raw_id, d1_id, d2_id] {
                w.add(id, &store.get(&id).unwrap()).unwrap();
            }
            w.finish().unwrap();
        }
        for id in [raw_id, d1_id, d2_id] {
            store.remove(&id).unwrap();
        }
        let store = Store::open_packed(&dir).unwrap();
        let m = store.object_meta(&d2_id).unwrap();
        assert_eq!(m.kind, ObjectKind::Delta);
        assert_eq!(m.parent, Some(d1_id));
        assert!(m.from_index, "sealed object must be answered from the index");
        assert!(m.shape.is_none(), "index answers carry no shape (no byte read)");
        let m = store.object_meta(&raw_id).unwrap();
        assert_eq!(m.kind, ObjectKind::Raw);
        assert_eq!(m.parent, None);

        // Opaque blobs: loose parse reports opaque.
        let blob = store.put_blob(b"not an MGTF object").unwrap();
        let m = store.object_meta(&blob).unwrap();
        assert_eq!(m.kind, ObjectKind::Opaque);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Regression: only the chain *tip* is a root, yet the mid-chain and
    /// base objects must survive GC — delta parents are strong refs,
    /// transitively.
    #[test]
    fn gc_transitively_keeps_delta_parents() {
        let store = Store::in_memory();
        let (raw_id, d1_id, d2_id) = mgtf_chain(&store);
        let junk = store.put_blob(b"junk-object").unwrap();
        let swept = store.gc(&[d2_id], tensor_refs).unwrap();
        assert_eq!(swept, vec![junk]);
        assert!(store.has(&raw_id) && store.has(&d1_id) && store.has(&d2_id));
    }

    /// Regression: a live mid-chain object going missing used to be
    /// silently treated as a leaf, so its parents were swept and the
    /// chain corrupted. GC must abort instead and sweep nothing.
    #[test]
    fn gc_aborts_on_unreadable_live_object() {
        let store = Store::in_memory();
        let (raw_id, d1_id, d2_id) = mgtf_chain(&store);
        let junk = store.put_blob(b"junk-object").unwrap();
        store.remove(&d1_id).unwrap(); // simulate loss/corruption
        let res = store.gc(&[d2_id], tensor_refs);
        assert!(res.is_err(), "gc must abort on an unreadable live object");
        // Nothing was swept — the chain base is still intact.
        assert!(store.has(&raw_id));
        assert!(store.has(&junk));
    }
}
