//! Content-addressed object store (§4, "content-based hashing").
//!
//! Every parameter tensor in every model of a lineage graph is stored at
//! most once, keyed by the SHA-256 of its *logical content* (dtype, shape,
//! raw values — matching the paper, which hashes tensor value and shape).
//! The stored payload for a key may be the raw tensor bytes or a
//! delta-compressed encoding against a parent tensor (see [`format`] and
//! the [`crate::delta`] pipeline) — the key always names the logical
//! content, so deduplication ("indirection") is automatic: a `put` of an
//! already-present key is a no-op dedup hit.
//!
//! Backends: on-disk (`.mgit/objects/aa/…`, one file per object, git-like
//! fan-out) and in-memory (benches, tests). Mark-and-sweep GC walks
//! caller-provided roots with a caller-provided reference extractor (the
//! store itself is payload-agnostic).

pub mod format;

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};
use sha2::{Digest, Sha256};

/// SHA-256 content id.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ObjectId(pub [u8; 32]);

impl ObjectId {
    pub fn hex(&self) -> String {
        self.0.iter().map(|b| format!("{b:02x}")).collect()
    }

    pub fn short(&self) -> String {
        self.hex()[..12].to_string()
    }

    pub fn from_hex(s: &str) -> Result<ObjectId> {
        if s.len() != 64 {
            bail!("object id must be 64 hex chars, got {}", s.len());
        }
        let mut out = [0u8; 32];
        for i in 0..32 {
            out[i] = u8::from_str_radix(&s[2 * i..2 * i + 2], 16)
                .map_err(|_| anyhow!("bad hex in object id"))?;
        }
        Ok(ObjectId(out))
    }
}

impl fmt::Debug for ObjectId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ObjectId({})", self.short())
    }
}

impl fmt::Display for ObjectId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.hex())
    }
}

/// Hash arbitrary bytes.
pub fn hash_bytes(bytes: &[u8]) -> ObjectId {
    let mut h = Sha256::new();
    h.update(bytes);
    ObjectId(h.finalize().into())
}

/// Hash a logical tensor: dtype code, dims, then the raw payload.
pub fn hash_tensor(dtype: crate::tensor::DType, shape: &[usize], payload: &[u8]) -> ObjectId {
    let mut h = Sha256::new();
    h.update([dtype.code(), shape.len() as u8]);
    for d in shape {
        h.update((*d as u64).to_le_bytes());
    }
    h.update(payload);
    ObjectId(h.finalize().into())
}

enum Backend {
    Disk { root: PathBuf },
    Mem { map: Mutex<HashMap<ObjectId, Vec<u8>>> },
}

/// Cumulative store statistics (for the Table-4/ablation benches).
#[derive(Debug, Default)]
pub struct StoreStats {
    pub puts: AtomicU64,
    pub dedup_hits: AtomicU64,
    pub bytes_written: AtomicU64,
}

pub struct Store {
    backend: Backend,
    pub stats: StoreStats,
}

impl Store {
    /// Open (creating if needed) an on-disk store rooted at `dir`.
    pub fn open(dir: &Path) -> Result<Store> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating object store at {}", dir.display()))?;
        Ok(Store {
            backend: Backend::Disk { root: dir.to_path_buf() },
            stats: StoreStats::default(),
        })
    }

    /// Volatile in-memory store (tests, benches).
    pub fn in_memory() -> Store {
        Store {
            backend: Backend::Mem { map: Mutex::new(HashMap::new()) },
            stats: StoreStats::default(),
        }
    }

    fn path_for(root: &Path, id: &ObjectId) -> PathBuf {
        let hex = id.hex();
        root.join(&hex[..2]).join(&hex[2..])
    }

    /// Store `bytes` under `id`. Returns `true` if newly written, `false`
    /// on a dedup hit (content already present).
    pub fn put(&self, id: ObjectId, bytes: &[u8]) -> Result<bool> {
        self.stats.puts.fetch_add(1, Ordering::Relaxed);
        if self.has(&id) {
            self.stats.dedup_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(false);
        }
        self.stats
            .bytes_written
            .fetch_add(bytes.len() as u64, Ordering::Relaxed);
        match &self.backend {
            Backend::Disk { root } => {
                let path = Self::path_for(root, &id);
                std::fs::create_dir_all(path.parent().unwrap())?;
                // Write-then-rename for atomicity.
                let tmp = path.with_extension("tmp");
                std::fs::write(&tmp, bytes)?;
                std::fs::rename(&tmp, &path)?;
            }
            Backend::Mem { map } => {
                map.lock().unwrap().insert(id, bytes.to_vec());
            }
        }
        Ok(true)
    }

    /// Convenience: hash bytes and store them under their own hash.
    pub fn put_blob(&self, bytes: &[u8]) -> Result<ObjectId> {
        let id = hash_bytes(bytes);
        self.put(id, bytes)?;
        Ok(id)
    }

    pub fn get(&self, id: &ObjectId) -> Result<Vec<u8>> {
        match &self.backend {
            Backend::Disk { root } => {
                let path = Self::path_for(root, id);
                std::fs::read(&path)
                    .with_context(|| format!("object {} not found", id.short()))
            }
            Backend::Mem { map } => map
                .lock()
                .unwrap()
                .get(id)
                .cloned()
                .ok_or_else(|| anyhow!("object {} not found", id.short())),
        }
    }

    pub fn has(&self, id: &ObjectId) -> bool {
        match &self.backend {
            Backend::Disk { root } => Self::path_for(root, id).exists(),
            Backend::Mem { map } => map.lock().unwrap().contains_key(id),
        }
    }

    pub fn remove(&self, id: &ObjectId) -> Result<()> {
        match &self.backend {
            Backend::Disk { root } => {
                let path = Self::path_for(root, id);
                if path.exists() {
                    std::fs::remove_file(path)?;
                }
            }
            Backend::Mem { map } => {
                map.lock().unwrap().remove(id);
            }
        }
        Ok(())
    }

    pub fn list(&self) -> Result<Vec<ObjectId>> {
        match &self.backend {
            Backend::Disk { root } => {
                let mut out = Vec::new();
                if !root.exists() {
                    return Ok(out);
                }
                for fan in std::fs::read_dir(root)? {
                    let fan = fan?;
                    if !fan.file_type()?.is_dir() {
                        continue;
                    }
                    let prefix = fan.file_name().to_string_lossy().to_string();
                    for obj in std::fs::read_dir(fan.path())? {
                        let name = obj?.file_name().to_string_lossy().to_string();
                        if name.ends_with(".tmp") {
                            continue;
                        }
                        if let Ok(id) = ObjectId::from_hex(&format!("{prefix}{name}")) {
                            out.push(id);
                        }
                    }
                }
                Ok(out)
            }
            Backend::Mem { map } => Ok(map.lock().unwrap().keys().copied().collect()),
        }
    }

    /// Total stored payload bytes (the numerator of compression ratios).
    pub fn stored_bytes(&self) -> Result<u64> {
        match &self.backend {
            Backend::Disk { root } => {
                let mut total = 0;
                for id in self.list()? {
                    total += std::fs::metadata(Self::path_for(root, &id))?.len();
                }
                Ok(total)
            }
            Backend::Mem { map } => {
                Ok(map.lock().unwrap().values().map(|v| v.len() as u64).sum())
            }
        }
    }

    /// Mark-and-sweep GC: keep everything reachable from `roots` through
    /// `refs` (which extracts outgoing ObjectIds from an object's payload).
    /// Returns the ids that were swept.
    pub fn gc(
        &self,
        roots: &[ObjectId],
        refs: impl Fn(&[u8]) -> Vec<ObjectId>,
    ) -> Result<Vec<ObjectId>> {
        let mut live: HashSet<ObjectId> = HashSet::new();
        let mut stack: Vec<ObjectId> = roots.to_vec();
        while let Some(id) = stack.pop() {
            if !live.insert(id) {
                continue;
            }
            if let Ok(bytes) = self.get(&id) {
                for r in refs(&bytes) {
                    if !live.contains(&r) {
                        stack.push(r);
                    }
                }
            }
        }
        let mut swept = Vec::new();
        for id in self.list()? {
            if !live.contains(&id) {
                self.remove(&id)?;
                swept.push(id);
            }
        }
        Ok(swept)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    #[test]
    fn hex_roundtrip() {
        let id = hash_bytes(b"hello");
        let back = ObjectId::from_hex(&id.hex()).unwrap();
        assert_eq!(id, back);
        assert!(ObjectId::from_hex("zz").is_err());
    }

    #[test]
    fn tensor_hash_depends_on_shape_and_dtype() {
        let payload = vec![0u8; 16];
        let a = hash_tensor(DType::F32, &[4], &payload);
        let b = hash_tensor(DType::F32, &[2, 2], &payload);
        let c = hash_tensor(DType::I32, &[4], &payload);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, hash_tensor(DType::F32, &[4], &payload));
    }

    fn exercise(store: &Store) {
        let id = store.put_blob(b"abc").unwrap();
        assert!(store.has(&id));
        assert_eq!(store.get(&id).unwrap(), b"abc");
        // dedup
        assert!(!store.put(id, b"abc").unwrap());
        assert_eq!(store.stats.dedup_hits.load(Ordering::Relaxed), 1);
        let id2 = store.put_blob(b"defg").unwrap();
        let mut listed = store.list().unwrap();
        listed.sort();
        let mut want = vec![id, id2];
        want.sort();
        assert_eq!(listed, want);
        assert_eq!(store.stored_bytes().unwrap(), 7);
        store.remove(&id).unwrap();
        assert!(!store.has(&id));
        assert!(store.get(&id).is_err());
    }

    #[test]
    fn memory_backend() {
        exercise(&Store::in_memory());
    }

    #[test]
    fn disk_backend() {
        let dir = std::env::temp_dir().join(format!("mgit-store-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        exercise(&Store::open(&dir).unwrap());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn gc_keeps_reachable_chain() {
        let store = Store::in_memory();
        // c <- b <- a (a references b, b references c) plus unreachable d.
        let c = store.put_blob(b"c-payload").unwrap();
        let b = store.put_blob(c.hex().as_bytes()).unwrap();
        let a = store.put_blob(b.hex().as_bytes()).unwrap();
        let d = store.put_blob(b"garbage").unwrap();
        let swept = store
            .gc(&[a], |bytes| {
                std::str::from_utf8(bytes)
                    .ok()
                    .and_then(|s| ObjectId::from_hex(s).ok())
                    .into_iter()
                    .collect()
            })
            .unwrap();
        assert_eq!(swept, vec![d]);
        assert!(store.has(&a) && store.has(&b) && store.has(&c));
        assert!(!store.has(&d));
    }
}
