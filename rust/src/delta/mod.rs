//! Delta compression pipeline (paper §4, Algorithm 1).
//!
//! Storing a child model against its parent:
//! 1. LCS-match parameters of equal shape between the two layouts
//!    ([`lcs`]) — identity mapping for same-architecture pairs;
//! 2. quantize each matched delta with the error-bounded quantizer
//!    ([`quant`], the Pallas kernel on the hot path);
//! 3. losslessly compress the quantized delta ([`codec`]);
//! 4. accept per-tensor only if the encoded object is smaller than raw;
//! 5. accept the *model* only if the reconstructed checkpoint passes the
//!    caller's accuracy check (MGit rejects compression whose test-accuracy
//!    drop exceeds the configured threshold) — hence the two-phase
//!    [`prepare_delta`] / [`commit`] API: candidates are built in memory,
//!    tested, and only then written to the store.
//!
//! Chains are recursive: a parent may itself be delta-compressed; loading
//! resolves the chain up to the first raw ancestor ([`load`]).
//!
//! ## Invariants
//!
//! * **Bit-exactness.** Decoding is the inverse of encoding down to
//!   the f32 bit pattern: `resolve(delta(child, parent)) == child` for
//!   every bit, not merely within `eps`. The quantizer bounds the
//!   *reconstruction* error during `prepare_delta` (the lossy step is
//!   taken once, before hashing), and all stored encodings of an id —
//!   raw, parent-delta, or a re-based delta chosen by `repack
//!   --similarity` — reproduce exactly the bytes that id was hashed
//!   from. [`reencode_exact`] enforces this when the repacker re-bases
//!   a chain: a candidate encoding that fails bitwise comparison is
//!   discarded.
//! * **Chain-depth bounds.** Every delta chain resolves in at most
//!   `max_chain_depth` parent hops (default 8; see
//!   [`crate::store::pack::RepackConfig`]). The repacker restores the
//!   bound by re-basing over-deep tails onto nearer ancestors,
//!   preserving ids.
//! * **Acyclicity.** Parent edges always point at previously-stored
//!   objects, and similarity-driven re-basing only selects bases that
//!   were processed earlier in the repack order, so chains can never
//!   form a cycle.
//!
//! The byte-level formats and the chunk-dedup layer built on top of
//! this pipeline ([`chunk`], [`similarity`]) are documented in
//! `docs/COMPRESSION.md`.
//!
//! ## Concurrent reconstruction
//!
//! The store tier is `Send + Sync` with lock-free pack reads, so chain
//! reconstruction can fan out across threads: [`load_parallel`] splits a
//! model's parameters over N resolver threads, and a shared bounded
//! [`ResolveCache`] keeps concurrent chain walks from redundantly
//! re-materializing the same raw ancestors (branches in a lineage graph
//! share base tensors by construction).

pub mod chunk;
pub mod codec;
pub mod lcs;
pub mod quant;
pub mod rle;
pub mod similarity;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

pub use codec::Codec;
pub use quant::{DeltaKernel, NativeKernel};

use crate::checkpoint::{ArchSpec, Checkpoint, ModelZoo};
use crate::store::format::TensorObject;
use crate::store::{hash_tensor, ObjectId, Store};
use crate::tensor::{bytes_to_i32, f32_to_bytes, i32_to_bytes, DType};
use crate::util::json::Json;

/// A model as stored in the CAS: arch + per-parameter content ids.
#[derive(Debug, Clone, PartialEq)]
pub struct StoredModel {
    /// Architecture name (resolves an `ArchSpec` in the zoo).
    pub arch: String,
    /// (parameter name, content id) pairs in layout order.
    pub params: Vec<(String, ObjectId)>,
}

impl StoredModel {
    /// Serialize for embedding in the lineage graph JSON.
    pub fn to_json(&self) -> Json {
        Json::obj().set("arch", self.arch.as_str()).set(
            "params",
            Json::Arr(
                self.params
                    .iter()
                    .map(|(n, id)| Json::obj().set("name", n.as_str()).set("id", id.hex()))
                    .collect(),
            ),
        )
    }

    /// Parse the [`StoredModel::to_json`] form.
    pub fn from_json(j: &Json) -> Result<StoredModel> {
        let mut params = Vec::new();
        for p in j.req_arr("params")? {
            params.push((
                p.req_str("name")?.to_string(),
                ObjectId::from_hex(p.req_str("id")?)?,
            ));
        }
        Ok(StoredModel { arch: j.req_str("arch")?.to_string(), params })
    }

    /// Content id of the parameter named `name`, if present.
    pub fn param_id(&self, name: &str) -> Option<ObjectId> {
        self.params.iter().find(|(n, _)| n == name).map(|(_, id)| *id)
    }

    /// All referenced tensor objects (GC roots contribution).
    pub fn refs(&self) -> Vec<ObjectId> {
        self.params.iter().map(|(_, id)| *id).collect()
    }
}

/// Configuration for delta compression.
#[derive(Debug, Clone, Copy)]
pub struct CompressConfig {
    /// Quantization error bound ε (paper default 1e-4).
    pub eps: f32,
    pub codec: Codec,
    /// Snap child values onto the quantization grid *before* computing
    /// deltas (the paper's G4 trick: "we quantize parameters before
    /// calculating deltas so that the sparsity is preserved" — exact
    /// zeros stay exact zeros through the delta chain).
    pub prequantize: bool,
}

impl Default for CompressConfig {
    fn default() -> Self {
        CompressConfig { eps: 1e-4, codec: Codec::Deflate, prequantize: false }
    }
}

/// Per-model compression outcome (feeds the Table-4 bench).
#[derive(Debug, Clone, Default)]
pub struct CompressReport {
    /// Raw f32 payload bytes of the model.
    pub raw_bytes: u64,
    /// Bytes of objects newly written for this model (dedup hits cost 0).
    pub stored_bytes: u64,
    /// Parameters in the model's layout.
    pub n_params: usize,
    /// Parameters stored delta-encoded.
    pub n_delta: usize,
    /// Parameters stored raw.
    pub n_raw: usize,
    /// Parameters that were dedup hits (already in the store).
    pub n_dedup: usize,
    /// Max |reconstructed − original| over all delta-encoded elements.
    pub max_abs_err: f64,
}

/// A prepared (not yet committed) compressed encoding of one model.
pub struct Candidate {
    pub model: StoredModel,
    /// (id, encoded object) pairs that `commit` will put.
    pub objects: Vec<(ObjectId, Vec<u8>)>,
    /// The reconstructed checkpoint m2' (what tests must be run against).
    pub checkpoint: Checkpoint,
    pub report: CompressReport,
}

/// Store a checkpoint without delta compression (content hashing only —
/// the paper's "Hash" configuration; identical tensors dedup across
/// models automatically).
pub fn store_raw(
    store: &Store,
    spec: &ArchSpec,
    ck: &Checkpoint,
) -> Result<(StoredModel, CompressReport)> {
    ck.check_arch(spec)?;
    let mut params = Vec::with_capacity(spec.layout.len());
    let mut report = CompressReport { n_params: spec.layout.len(), ..Default::default() };
    for (entry, slice) in ck.iter_params(spec) {
        let payload = f32_to_bytes(slice);
        report.raw_bytes += payload.len() as u64;
        let id = hash_tensor(DType::F32, &entry.shape, &payload);
        let obj = TensorObject::Raw { dtype: DType::F32, shape: entry.shape.clone(), payload };
        let encoded = obj.encode();
        if store.put(id, &encoded)? {
            report.stored_bytes += encoded.len() as u64;
            report.n_raw += 1;
        } else {
            report.n_dedup += 1;
        }
        params.push((entry.name.clone(), id));
    }
    Ok((StoredModel { arch: spec.name.clone(), params }, report))
}

/// Build a delta-compressed candidate of `child` against `parent`.
///
/// `parent_model` supplies the content ids the delta objects point at; the
/// parent checkpoint must be the *reconstructed* parent (i.e. what `load`
/// returns), so chains stay consistent.
#[allow(clippy::too_many_arguments)]
pub fn prepare_delta(
    store: &Store,
    child_spec: &ArchSpec,
    child: &Checkpoint,
    parent_spec: &ArchSpec,
    parent: &Checkpoint,
    parent_model: &StoredModel,
    cfg: CompressConfig,
    kernel: &dyn DeltaKernel,
) -> Result<Candidate> {
    child.check_arch(child_spec)?;
    parent.check_arch(parent_spec)?;
    let matches = lcs::match_params(&parent_spec.layout, &child_spec.layout);
    let matched_child: HashMap<usize, usize> =
        matches.iter().map(|&(pi, ci)| (ci, pi)).collect();

    let mut report = CompressReport { n_params: child_spec.layout.len(), ..Default::default() };
    let mut params = Vec::with_capacity(child_spec.layout.len());
    let mut objects = Vec::new();
    let mut flat = child.flat.clone();

    let grid = quant::step(cfg.eps);
    let snap = |c: f32| if c == 0.0 { 0.0 } else { (c / grid + 0.5).floor() * grid };
    for (ci, entry) in child_spec.layout.iter().enumerate() {
        let raw_child = &child.flat[entry.offset..entry.offset + entry.size];
        let snapped: Vec<f32>;
        let child_slice: &[f32] = if cfg.prequantize {
            snapped = raw_child.iter().map(|&c| snap(c)).collect();
            &snapped
        } else {
            raw_child
        };
        report.raw_bytes += (entry.size * 4) as u64;

        // Try delta encoding for LCS-matched tensors.
        let mut done = false;
        if let Some(&pi) = matched_child.get(&ci) {
            let pentry = &parent_spec.layout[pi];
            let parent_slice = &parent.flat[pentry.offset..pentry.offset + pentry.size];
            let parent_id = parent_model
                .param_id(&pentry.name)
                .ok_or_else(|| anyhow!("parent model missing param {}", pentry.name))?;
            let q = if cfg.prequantize {
                // Integer grid deltas: kp - kc, exact for grid parents.
                parent_slice
                    .iter()
                    .zip(child_slice)
                    .map(|(&p, &c)| {
                        ((p / grid + 0.5).floor() - (c / grid + 0.5).floor()) as i32
                    })
                    .collect::<Vec<i32>>()
            } else {
                kernel.quantize(parent_slice, child_slice, cfg.eps)?
            };
            let compressed = cfg.codec.compress(&i32_to_bytes(&q))?;
            let raw_len = entry.size * 4;
            // Per-tensor acceptance: encoded object must beat raw storage.
            if compressed.len() + 64 < raw_len {
                let rec = if cfg.prequantize {
                    parent_slice
                        .iter()
                        .zip(&q)
                        .map(|(&p, &qi)| ((p / grid + 0.5).floor() - qi as f32) * grid)
                        .collect::<Vec<f32>>()
                } else {
                    kernel.dequantize(parent_slice, &q, cfg.eps)?
                };
                for (r, c) in rec.iter().zip(child_slice) {
                    report.max_abs_err = report.max_abs_err.max((r - c).abs() as f64);
                }
                let payload = f32_to_bytes(&rec);
                let id = hash_tensor(DType::F32, &entry.shape, &payload);
                let obj = TensorObject::Delta {
                    dtype: DType::F32,
                    shape: entry.shape.clone(),
                    parent: parent_id,
                    eps: cfg.eps,
                    codec: cfg.codec.code(),
                    n_quant: entry.size,
                    grid: cfg.prequantize,
                    payload: compressed,
                };
                let encoded = obj.encode();
                if store.has(&id) {
                    report.n_dedup += 1;
                } else {
                    report.stored_bytes += encoded.len() as u64;
                    report.n_delta += 1;
                    objects.push((id, encoded));
                }
                flat[entry.offset..entry.offset + entry.size].copy_from_slice(&rec);
                params.push((entry.name.clone(), id));
                done = true;
            }
        }
        if !done {
            // Store raw (unmatched shape, or delta didn't save space).
            let payload = f32_to_bytes(child_slice);
            let id = hash_tensor(DType::F32, &entry.shape, &payload);
            let obj =
                TensorObject::Raw { dtype: DType::F32, shape: entry.shape.clone(), payload };
            let encoded = obj.encode();
            if store.has(&id) {
                report.n_dedup += 1;
            } else {
                report.stored_bytes += encoded.len() as u64;
                report.n_raw += 1;
                objects.push((id, encoded));
            }
            params.push((entry.name.clone(), id));
        }
    }

    Ok(Candidate {
        model: StoredModel { arch: child_spec.name.clone(), params },
        objects,
        checkpoint: Checkpoint { arch: child_spec.name.clone(), flat },
        report,
    })
}

/// Write a prepared candidate's objects into the store.
pub fn commit(store: &Store, candidate: &Candidate) -> Result<()> {
    for (id, bytes) in &candidate.objects {
        store.put(*id, bytes)?;
    }
    Ok(())
}

/// Algorithm 1 end-to-end: try delta compression; accept only if it saves
/// space *and* the reconstructed model passes `check` (accuracy threshold);
/// otherwise store raw. Returns (stored model, the checkpoint that should
/// be considered the model's content from now on, report, accepted?).
#[allow(clippy::too_many_arguments)]
pub fn delta_compress_checked(
    store: &Store,
    child_spec: &ArchSpec,
    child: &Checkpoint,
    parent_spec: &ArchSpec,
    parent: &Checkpoint,
    parent_model: &StoredModel,
    cfg: CompressConfig,
    kernel: &dyn DeltaKernel,
    check: impl FnOnce(&Checkpoint) -> Result<bool>,
) -> Result<(StoredModel, Checkpoint, CompressReport, bool)> {
    let cand = prepare_delta(
        store, child_spec, child, parent_spec, parent, parent_model, cfg, kernel,
    )?;
    let saves_space = cand.report.stored_bytes < cand.report.raw_bytes;
    if saves_space && check(&cand.checkpoint)? {
        commit(store, &cand)?;
        let Candidate { model, checkpoint, report, .. } = cand;
        Ok((model, checkpoint, report, true))
    } else {
        let (model, report) = store_raw(store, child_spec, child)?;
        Ok((model, child.clone(), report, false))
    }
}

/// Load a stored model, resolving delta chains recursively.
pub fn load(
    store: &Store,
    zoo: &ModelZoo,
    model: &StoredModel,
    kernel: &dyn DeltaKernel,
) -> Result<Checkpoint> {
    let spec = zoo.arch(&model.arch)?;
    let mut cache: HashMap<ObjectId, Vec<f32>> = HashMap::new();
    let mut flat = vec![0f32; spec.param_count];
    for (name, id) in &model.params {
        let entry = spec.entry(name)?;
        let values = resolve_tensor(store, *id, kernel, &mut cache, 0)?;
        if values.len() != entry.size {
            bail!(
                "stored tensor {} has {} elements, layout wants {}",
                name,
                values.len(),
                entry.size
            );
        }
        flat[entry.offset..entry.offset + entry.size].copy_from_slice(&values);
    }
    Ok(Checkpoint { arch: model.arch.clone(), flat })
}

/// Resolve one tensor object to f32 values, following parent pointers.
pub fn resolve_tensor(
    store: &Store,
    id: ObjectId,
    kernel: &dyn DeltaKernel,
    cache: &mut HashMap<ObjectId, Vec<f32>>,
    depth: usize,
) -> Result<Vec<f32>> {
    if let Some(v) = cache.get(&id) {
        return Ok(v.clone());
    }
    if depth > 10_000 {
        bail!("delta chain too deep (cycle?) at {}", id.short());
    }
    let obj = TensorObject::decode(&store.get(&id)?)?;
    let values = resolve_object(store, &obj, kernel, cache, depth)?;
    cache.insert(id, values.clone());
    Ok(values)
}

/// Resolve an already-decoded object's values, following its parent chain
/// through `store`. Lets callers resolve a *specific physical copy* of an
/// object (e.g. the bytes inside one pack during `verify-pack`) while the
/// ancestors — value-identical by content addressing — come from wherever
/// the store finds them.
pub fn resolve_object(
    store: &Store,
    obj: &TensorObject,
    kernel: &dyn DeltaKernel,
    cache: &mut HashMap<ObjectId, Vec<f32>>,
    depth: usize,
) -> Result<Vec<f32>> {
    match obj {
        TensorObject::Raw { dtype, payload, .. } => raw_values(*dtype, payload),
        TensorObject::Delta { parent, eps, codec, n_quant, grid, payload, .. } => {
            let parent_vals = resolve_tensor(store, *parent, kernel, cache, depth + 1)?;
            apply_delta(&parent_vals, *eps, *codec, *n_quant, *grid, payload, kernel)
        }
    }
}

/// Decode a `Raw` object's payload to f32 values. Shared by the serial
/// and shared-cache resolvers so the two paths cannot drift — ids hash
/// reconstructed values, so both must stay bit-identical.
fn raw_values(dtype: DType, payload: &[u8]) -> Result<Vec<f32>> {
    if dtype != DType::F32 {
        bail!("expected f32 tensor object");
    }
    Ok(crate::tensor::bytes_to_f32(payload))
}

/// Reconstruct a delta object's values from its (already resolved)
/// parent values: decompress the quantized payload, then dequantize —
/// grid mode is the exact sparsity-preserving reconstruction, normal
/// mode runs the kernel.
fn apply_delta(
    parent_vals: &[f32],
    eps: f32,
    codec: u8,
    n_quant: usize,
    grid: bool,
    payload: &[u8],
    kernel: &dyn DeltaKernel,
) -> Result<Vec<f32>> {
    let codec = Codec::from_code(codec)?;
    let qbytes = codec.decompress(payload, n_quant * 4)?;
    let q = bytes_to_i32(&qbytes);
    if grid {
        // Exact grid reconstruction (sparsity-preserving):
        // rec = (round(parent/step) − q) · step.
        let step = quant::step(eps);
        Ok(parent_vals
            .iter()
            .zip(&q)
            .map(|(&p, &qi)| ((p / step + 0.5).floor() - qi as f32) * step)
            .collect())
    } else {
        kernel.dequantize(parent_vals, &q, eps)
    }
}

// ---------------------------------------------------------------------------
// Concurrent chain reconstruction
// ---------------------------------------------------------------------------

/// Bounded, thread-safe cache of resolved tensor values, shared across
/// concurrent chain walks.
///
/// Delta chains in a lineage graph converge on shared ancestors (every
/// branch of a model family bottoms out in the same pretrained bases),
/// so concurrent readers resolving different models repeatedly need the
/// same upstream values. Entries are `Arc`-shared — a hit costs one
/// clone of the pointer, not of the values — and eviction is
/// least-recently-used under two bounds: an entry capacity and an
/// optional byte budget ([`ResolveCache::with_max_bytes`]; tensors are
/// large, so counting entries alone would not bound peak memory). A hit
/// refreshes the entry's recency, keeping hot shared bases resident.
///
/// Two threads racing to resolve the same object may both do the work
/// once, but [`ResolveCache::insert`] keeps a single copy and both get
/// the same `Arc` back; results are deterministic either way.
pub struct ResolveCache {
    inner: Mutex<ResolveCacheInner>,
    capacity: usize,
    max_bytes: u64,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Cumulative LRU evictions. Updated while `inner`'s lock is already
    /// held (inserts), read lock-free — observability must not add lock
    /// acquisitions to any cache path.
    evictions: AtomicU64,
    /// Lock-free mirror of `inner.bytes`, refreshed after each insert.
    resident: AtomicU64,
}

struct ResolveCacheInner {
    /// id -> (values, last-used stamp).
    map: HashMap<ObjectId, (Arc<Vec<f32>>, u64)>,
    /// Total payload bytes currently cached (4 bytes per f32).
    bytes: u64,
    /// Monotonic recency clock.
    tick: u64,
}

impl ResolveCache {
    /// A cache holding at most `capacity` resolved tensors (min 1) with
    /// no byte budget — use [`ResolveCache::with_max_bytes`] when the
    /// tensors are large enough that entry count alone can't bound
    /// memory.
    pub fn new(capacity: usize) -> ResolveCache {
        Self::with_max_bytes(capacity, u64::MAX)
    }

    /// A cache bounded by both entry count and total payload bytes.
    /// Eviction makes room before each insert; the freshly inserted
    /// tensor is always kept, so a single tensor larger than the whole
    /// budget still caches (alone) rather than thrashing.
    pub fn with_max_bytes(capacity: usize, max_bytes: u64) -> ResolveCache {
        ResolveCache {
            inner: Mutex::new(ResolveCacheInner {
                map: HashMap::new(),
                bytes: 0,
                tick: 0,
            }),
            capacity: capacity.max(1),
            max_bytes,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            resident: AtomicU64::new(0),
        }
    }

    /// Look up previously resolved values for `id` (refreshing its LRU
    /// recency on a hit).
    pub fn get(&self, id: &ObjectId) -> Option<Arc<Vec<f32>>> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(id) {
            Some((v, stamp)) => {
                *stamp = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert resolved values for `id`, evicting least-recently-used
    /// entries until both the entry and byte bounds hold. If another
    /// thread inserted `id` first, its copy wins and is returned (one
    /// shared allocation per object).
    pub fn insert(&self, id: ObjectId, values: Vec<f32>) -> Arc<Vec<f32>> {
        let new_bytes = values.len() as u64 * 4;
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some((existing, stamp)) = inner.map.get_mut(&id) {
            *stamp = tick;
            return existing.clone();
        }
        while !inner.map.is_empty()
            && (inner.map.len() >= self.capacity
                || inner.bytes.saturating_add(new_bytes) > self.max_bytes)
        {
            // O(capacity) scan, but only on insert under pressure —
            // cheap next to materializing even one tensor.
            let oldest = inner
                .map
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(k, _)| *k);
            match oldest {
                Some(k) => {
                    if let Some((v, _)) = inner.map.remove(&k) {
                        inner.bytes -= v.len() as u64 * 4;
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                }
                None => break,
            }
        }
        let arc = Arc::new(values);
        inner.map.insert(id, (arc.clone(), tick));
        inner.bytes += new_bytes;
        self.resident.store(inner.bytes, Ordering::Relaxed);
        arc
    }

    /// Number of currently cached tensors.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// Whether the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.inner.lock().unwrap().map.is_empty()
    }

    /// Cumulative (hits, misses) since construction.
    pub fn counters(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }

    /// Cumulative LRU evictions since construction (lock-free read).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Payload bytes currently resident, as of the last insert
    /// (lock-free read of a mirror; see `len` for an exact locked count).
    pub fn resident_bytes(&self) -> u64 {
        self.resident.load(Ordering::Relaxed)
    }

    /// Fraction of lookups served from cache (0.0 when none happened).
    pub fn hit_rate(&self) -> f64 {
        let (h, m) = self.counters();
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }
}

/// [`resolve_tensor`] against a shared [`ResolveCache`]: safe to call
/// from many threads at once over one `&Store`. Returns the cached
/// `Arc` so hits don't copy tensor values.
pub fn resolve_tensor_shared(
    store: &Store,
    id: ObjectId,
    kernel: &dyn DeltaKernel,
    cache: &ResolveCache,
    depth: usize,
) -> Result<Arc<Vec<f32>>> {
    if let Some(v) = cache.get(&id) {
        return Ok(v);
    }
    if depth > 10_000 {
        bail!("delta chain too deep (cycle?) at {}", id.short());
    }
    let obj = TensorObject::decode(&store.get(&id)?)?;
    let values = match &obj {
        TensorObject::Raw { dtype, payload, .. } => raw_values(*dtype, payload)?,
        TensorObject::Delta { parent, eps, codec, n_quant, grid, payload, .. } => {
            let parent_vals =
                resolve_tensor_shared(store, *parent, kernel, cache, depth + 1)?;
            apply_delta(&parent_vals, *eps, *codec, *n_quant, *grid, payload, kernel)?
        }
    };
    Ok(cache.insert(id, values))
}

/// [`load`] resolving every chain through a shared [`ResolveCache`]
/// (single-threaded; the cache may be shared with other threads).
pub fn load_with_cache(
    store: &Store,
    zoo: &ModelZoo,
    model: &StoredModel,
    kernel: &dyn DeltaKernel,
    cache: &ResolveCache,
) -> Result<Checkpoint> {
    let spec = zoo.arch(&model.arch)?;
    let mut flat = vec![0f32; spec.param_count];
    for (name, id) in &model.params {
        let entry = spec.entry(name)?;
        let values = resolve_tensor_shared(store, *id, kernel, cache, 0)?;
        if values.len() != entry.size {
            bail!(
                "stored tensor {} has {} elements, layout wants {}",
                name,
                values.len(),
                entry.size
            );
        }
        flat[entry.offset..entry.offset + entry.size].copy_from_slice(&values);
    }
    Ok(Checkpoint { arch: model.arch.clone(), flat })
}

/// Load a stored model with chain reconstruction fanned out over
/// `threads` resolver threads sharing `cache`.
///
/// The parameter list is split into contiguous slabs, one per thread;
/// each thread cold-resolves its slab's chains against the same `&Store`
/// (lock-free pack reads) and the merged flat vector is returned. The
/// result is bit-identical to [`load`]. The kernel must be `Sync`
/// ([`NativeKernel`] is; pass `threads = 1` to stay single-threaded).
pub fn load_parallel(
    store: &Store,
    zoo: &ModelZoo,
    model: &StoredModel,
    kernel: &(dyn DeltaKernel + Sync),
    cache: &ResolveCache,
    threads: usize,
) -> Result<Checkpoint> {
    let spec = zoo.arch(&model.arch)?;
    let n = threads.max(1).min(model.params.len().max(1));
    if n <= 1 {
        return load_with_cache(store, zoo, model, kernel, cache);
    }
    let mut items = Vec::with_capacity(model.params.len());
    for (name, id) in &model.params {
        let entry = spec.entry(name)?;
        items.push((entry.offset, entry.size, *id, name.as_str()));
    }
    let chunk = (items.len() + n - 1) / n;
    let mut flat = vec![0f32; spec.param_count];
    let results: Vec<Result<Vec<(usize, usize, Arc<Vec<f32>>)>>> =
        std::thread::scope(|s| {
            let handles: Vec<_> = items
                .chunks(chunk)
                .map(|slab| {
                    s.spawn(move || -> Result<Vec<(usize, usize, Arc<Vec<f32>>)>> {
                        slab.iter()
                            .map(|&(offset, size, id, name)| {
                                let v =
                                    resolve_tensor_shared(store, id, kernel, cache, 0)?;
                                if v.len() != size {
                                    bail!(
                                        "stored tensor {} has {} elements, layout \
                                         wants {}",
                                        name,
                                        v.len(),
                                        size
                                    );
                                }
                                Ok((offset, size, v))
                            })
                            .collect()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("resolver thread panicked"))
                .collect()
        });
    for r in results {
        for (offset, size, v) in r? {
            flat[offset..offset + size].copy_from_slice(&v);
        }
    }
    Ok(Checkpoint { arch: model.arch.clone(), flat })
}

/// Re-encode a tensor's resolved values as a delta against a (usually
/// nearer) ancestor — the repacker's chain re-basing hook
/// ([`crate::store::pack::repack()`]).
///
/// Object ids name *logical content*, so a re-encoding is only usable if
/// reconstruction is **bit-exact** (the id keeps matching its content)
/// and the encoded object still beats raw storage. Returns `None` when
/// either condition fails; the caller then falls back to a new raw base,
/// which preserves the id by construction.
#[allow(clippy::too_many_arguments)]
pub fn reencode_exact(
    child_vals: &[f32],
    parent_vals: &[f32],
    parent_id: ObjectId,
    shape: &[usize],
    eps: f32,
    codec: Codec,
    grid: bool,
    kernel: &dyn DeltaKernel,
) -> Result<Option<TensorObject>> {
    if parent_vals.len() != child_vals.len() {
        return Ok(None);
    }
    let (q, rec): (Vec<i32>, Vec<f32>) = if grid {
        // Integer grid deltas (G4 mode): both tensors live on the k·step
        // grid, so the delta is exact integers and reconstruction is
        // (round(parent/step) − q)·step.
        let s = quant::step(eps);
        let q: Vec<i32> = parent_vals
            .iter()
            .zip(child_vals)
            .map(|(&p, &c)| ((p / s + 0.5).floor() - (c / s + 0.5).floor()) as i32)
            .collect();
        let rec = parent_vals
            .iter()
            .zip(&q)
            .map(|(&p, &qi)| ((p / s + 0.5).floor() - qi as f32) * s)
            .collect();
        (q, rec)
    } else {
        let q = kernel.quantize(parent_vals, child_vals, eps)?;
        let rec = kernel.dequantize(parent_vals, &q, eps)?;
        (q, rec)
    };
    // Bit-exactness (not mere f32 equality: -0.0 == 0.0 but the bytes —
    // and hence the content hash — would differ).
    if !rec.iter().zip(child_vals).all(|(a, b)| a.to_bits() == b.to_bits()) {
        return Ok(None);
    }
    let compressed = codec.compress(&i32_to_bytes(&q))?;
    // Same per-tensor acceptance rule as prepare_delta.
    if compressed.len() + 64 >= child_vals.len() * 4 {
        return Ok(None);
    }
    Ok(Some(TensorObject::Delta {
        dtype: DType::F32,
        shape: shape.to_vec(),
        parent: parent_id,
        eps,
        codec: codec.code(),
        n_quant: child_vals.len(),
        grid,
        payload: compressed,
    }))
}

/// Length of the delta chain from `id` up to its first raw ancestor.
///
/// Chain discovery is metadata-only ([`Store::object_meta`]): links
/// sealed in v2 packs are followed straight from the pack index without
/// reading the objects at all; loose and v1-packed links cost a
/// header-only parse. No payload is ever decoded.
pub fn chain_depth(store: &Store, id: ObjectId) -> Result<usize> {
    let mut depth = 0;
    let mut cur = id;
    loop {
        match store.object_meta(&cur)?.parent {
            None => return Ok(depth),
            Some(parent) => {
                depth += 1;
                cur = parent;
                if depth > 10_000 {
                    bail!("delta chain too deep (cycle?)");
                }
            }
        }
    }
}

/// Size of the "Full" baseline encodings of Table 4: the whole model's
/// values quantized (optionally) and compressed with `codec`, independent
/// of any parent.
pub fn full_model_compressed_size(
    ck: &Checkpoint,
    codec: Codec,
    eps: f32,
    quantize: bool,
) -> Result<(usize, Checkpoint)> {
    let raw = if quantize {
        let s = quant::step(eps);
        let q: Vec<i32> = ck.flat.iter().map(|&p| (p / s + 0.5).floor() as i32).collect();
        let rec: Vec<f32> = q.iter().map(|&qi| qi as f32 * s).collect();
        let bytes = i32_to_bytes(&q);
        let rec_ck = Checkpoint { arch: ck.arch.clone(), flat: rec };
        return Ok((codec.compress(&bytes)?.len(), rec_ck));
    } else {
        f32_to_bytes(&ck.flat)
    };
    Ok((codec.compress(&raw)?.len(), ck.clone()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::tiny_zoo;
    use crate::util::rng::Rng;

    fn perturbed(ck: &Checkpoint, scale: f32, seed: u64) -> Checkpoint {
        let mut rng = Rng::new(seed);
        let flat = ck.flat.iter().map(|&x| x + rng.normal_f32(0.0, scale)).collect();
        Checkpoint { arch: ck.arch.clone(), flat }
    }

    #[test]
    fn raw_store_dedups_identical_models() {
        let zoo = tiny_zoo();
        let spec = zoo.arch("t0").unwrap();
        let store = Store::in_memory();
        let ck = Checkpoint::init(spec, 1);
        let (m1, r1) = store_raw(&store, spec, &ck).unwrap();
        let (m2, r2) = store_raw(&store, spec, &ck).unwrap();
        assert_eq!(m1, m2);
        assert_eq!(r1.n_raw, 3);
        assert_eq!(r2.n_dedup, 3);
        assert_eq!(r2.stored_bytes, 0);
        let loaded = load(&store, &zoo, &m1, &NativeKernel).unwrap();
        assert_eq!(loaded.flat, ck.flat);
    }

    /// Build a larger fake spec so the per-tensor size test is meaningful.
    fn big_zoo() -> ModelZoo {
        let text = r#"{
          "vocab": 16, "max_seq": 4, "n_classes": 2, "batch": 2,
          "delta_chunk": 64,
          "special_tokens": {"cls": 14, "mask": 15, "ignore_label": -100},
          "archs": {"big": {
              "d_model": 2, "n_layers": 1, "n_heads": 1, "d_ff": 4,
              "param_count": 4096,
              "layout": [
                {"name":"w.a","shape":[32,64],"offset":0,"size":2048,"init":"normal"},
                {"name":"w.b","shape":[2048],"offset":2048,"size":2048,"init":"normal"}
              ],
              "dag": {"nodes": [{"id":"a","op":"linear","attrs":"x","params":["w.a"]},
                                {"id":"b","op":"linear","attrs":"y","params":["w.b"]}],
                      "edges": [["a","b"]]}
          }},
          "artifacts": {"big": {}},
          "delta_kernels": {"quant": "q", "dequant": "d"}
        }"#;
        ModelZoo::from_json(&crate::util::json::parse(text).unwrap()).unwrap()
    }

    #[test]
    fn delta_roundtrip_within_bound() {
        let zoo = big_zoo();
        let spec = zoo.arch("big").unwrap();
        let store = Store::in_memory();
        let parent = Checkpoint::init(spec, 1);
        let child = perturbed(&parent, 5e-5, 2);
        let (pm, _) = store_raw(&store, spec, &parent).unwrap();
        let cfg = CompressConfig::default();
        let cand =
            prepare_delta(&store, spec, &child, spec, &parent, &pm, cfg, &NativeKernel).unwrap();
        assert!(cand.report.n_delta > 0, "report: {:?}", cand.report);
        assert!(cand.report.stored_bytes < cand.report.raw_bytes);
        assert!(cand.report.max_abs_err <= quant::step(cfg.eps) as f64 * 1.001);
        commit(&store, &cand).unwrap();
        let loaded = load(&store, &zoo, &cand.model, &NativeKernel).unwrap();
        assert_eq!(loaded.flat, cand.checkpoint.flat); // bit-exact after commit
        // and close to the original child
        for (a, b) in loaded.flat.iter().zip(&child.flat) {
            assert!((a - b).abs() <= quant::step(cfg.eps) * 1.001);
        }
    }

    #[test]
    fn rejects_when_check_fails() {
        let zoo = big_zoo();
        let spec = zoo.arch("big").unwrap();
        let store = Store::in_memory();
        let parent = Checkpoint::init(spec, 1);
        let child = perturbed(&parent, 5e-5, 2);
        let (pm, _) = store_raw(&store, spec, &parent).unwrap();
        let (model, ck, _report, accepted) = delta_compress_checked(
            &store, spec, &child, spec, &parent, &pm,
            CompressConfig::default(), &NativeKernel,
            |_rec| Ok(false), // accuracy check fails -> must store raw
        )
        .unwrap();
        assert!(!accepted);
        assert_eq!(ck.flat, child.flat);
        let loaded = load(&store, &zoo, &model, &NativeKernel).unwrap();
        assert_eq!(loaded.flat, child.flat); // lossless path
    }

    #[test]
    fn recursive_chain_resolves() {
        let zoo = big_zoo();
        let spec = zoo.arch("big").unwrap();
        let store = Store::in_memory();
        let cfg = CompressConfig::default();

        let v0 = Checkpoint::init(spec, 1);
        let (m0, _) = store_raw(&store, spec, &v0).unwrap();
        let mut prev_ck = v0;
        let mut prev_m = m0;
        let mut originals = Vec::new();
        // Noise well above the quantization step so every version's
        // reconstruction differs from its parent (distinct content hashes,
        // hence a real 5-deep chain).
        for ver in 0..5u64 {
            let child = perturbed(&prev_ck, 3e-4, 10 + ver);
            originals.push(child.clone());
            let cand = prepare_delta(
                &store, spec, &child, spec, &prev_ck, &prev_m, cfg, &NativeKernel,
            )
            .unwrap();
            commit(&store, &cand).unwrap();
            prev_ck = cand.checkpoint;
            prev_m = cand.model;
        }
        // Depth of the last version's first param should be 5.
        let id = prev_m.param_id("w.a").unwrap();
        assert_eq!(chain_depth(&store, id).unwrap(), 5);
        let loaded = load(&store, &zoo, &prev_m, &NativeKernel).unwrap();
        // Error accumulates but stays bounded by 5 * step.
        let bound = 5.0 * quant::step(cfg.eps) * 1.01;
        for (a, b) in loaded.flat.iter().zip(&originals.last().unwrap().flat) {
            assert!((a - b).abs() <= bound);
        }
    }

    #[test]
    fn chain_depth_zero_for_raw_and_counts_links() {
        let zoo = big_zoo();
        let spec = zoo.arch("big").unwrap();
        let store = Store::in_memory();
        let cfg = CompressConfig::default();
        let v0 = Checkpoint::init(spec, 9);
        let (m0, _) = store_raw(&store, spec, &v0).unwrap();
        for (_, id) in &m0.params {
            assert_eq!(chain_depth(&store, *id).unwrap(), 0);
        }
        // One delta hop -> depth 1 for delta-encoded params.
        let child = perturbed(&v0, 5e-5, 10);
        let cand =
            prepare_delta(&store, spec, &child, spec, &v0, &m0, cfg, &NativeKernel).unwrap();
        assert!(cand.report.n_delta > 0);
        commit(&store, &cand).unwrap();
        let id = cand.model.param_id("w.a").unwrap();
        assert_eq!(chain_depth(&store, id).unwrap(), 1);
        // Missing object is an error, not depth 0.
        assert!(chain_depth(&store, crate::store::hash_bytes(b"absent")).is_err());
    }

    /// Two children delta-compressed against the *same* raw ancestor: the
    /// chain branches, both branches resolve independently, and the
    /// shared ancestor is stored once.
    #[test]
    fn branching_chains_share_one_raw_ancestor() {
        let zoo = big_zoo();
        let spec = zoo.arch("big").unwrap();
        let store = Store::in_memory();
        let cfg = CompressConfig::default();
        let root = Checkpoint::init(spec, 21);
        let (rm, _) = store_raw(&store, spec, &root).unwrap();

        let mut children = Vec::new();
        for seed in [100u64, 200u64] {
            let child = perturbed(&root, 3e-4, seed);
            let cand =
                prepare_delta(&store, spec, &child, spec, &root, &rm, cfg, &NativeKernel)
                    .unwrap();
            assert!(cand.report.n_delta > 0);
            commit(&store, &cand).unwrap();
            children.push((child, cand.model));
        }
        // Both branch tips hang off the same raw ancestor object.
        let parent_of = |id: ObjectId| match TensorObject::decode(&store.get(&id).unwrap())
            .unwrap()
        {
            TensorObject::Delta { parent, .. } => parent,
            TensorObject::Raw { .. } => panic!("expected delta"),
        };
        let a = children[0].1.param_id("w.a").unwrap();
        let b = children[1].1.param_id("w.a").unwrap();
        assert_ne!(a, b, "distinct children must have distinct content");
        assert_eq!(parent_of(a), parent_of(b));
        assert_eq!(parent_of(a), rm.param_id("w.a").unwrap());
        assert_eq!(chain_depth(&store, a).unwrap(), 1);
        assert_eq!(chain_depth(&store, b).unwrap(), 1);
        // Recursive load resolves each branch to its own content.
        for (child, model) in &children {
            let loaded = load(&store, &zoo, model, &NativeKernel).unwrap();
            for (x, y) in loaded.flat.iter().zip(&child.flat) {
                assert!((x - y).abs() <= quant::step(cfg.eps) * 1.001);
            }
        }
    }

    #[test]
    fn reencode_exact_respects_bit_exactness_and_size() {
        let mut rng = crate::util::rng::Rng::new(5);
        let n = 512usize;
        let parent: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let eps = 1e-4f32;
        // A child that IS a quantized delta of parent reconstructs
        // bit-exactly, so re-encoding against the same parent succeeds.
        let q: Vec<i32> = (0..n as i32).map(|i| (i % 7) - 3).collect();
        let child = NativeKernel.dequantize(&parent, &q, eps).unwrap();
        let pid = crate::store::hash_bytes(b"parent");
        let obj = reencode_exact(
            &child, &parent, pid, &[n], eps, Codec::Deflate, false, &NativeKernel,
        )
        .unwrap()
        .expect("exact re-encoding must be accepted");
        match obj {
            TensorObject::Delta { parent: p, n_quant, .. } => {
                assert_eq!(p, pid);
                assert_eq!(n_quant, n);
            }
            _ => panic!("expected delta"),
        }
        // An unrelated child almost never reconstructs bit-exactly.
        let unrelated: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let r = reencode_exact(
            &unrelated, &parent, pid, &[n], eps, Codec::Deflate, false, &NativeKernel,
        )
        .unwrap();
        assert!(r.is_none(), "inexact re-encoding must be rejected");
        // Length mismatch is rejected, not an error.
        assert!(reencode_exact(
            &child[..10],
            &parent,
            pid,
            &[10],
            eps,
            Codec::Deflate,
            false,
            &NativeKernel
        )
        .unwrap()
        .is_none());
    }

    #[test]
    fn resolve_cache_is_bounded_and_deduped() {
        let cache = ResolveCache::new(4);
        for i in 0..20u32 {
            cache.insert(crate::store::hash_bytes(&i.to_le_bytes()), vec![i as f32]);
        }
        assert!(cache.len() <= 4, "cache exceeded its capacity");
        // The most recent insert survives eviction.
        let id = crate::store::hash_bytes(&19u32.to_le_bytes());
        let v = cache.get(&id).expect("most recent entry evicted");
        assert_eq!(*v, vec![19.0f32]);
        // Re-inserting an existing id keeps the first copy.
        let again = cache.insert(id, vec![999.0]);
        assert_eq!(*again, vec![19.0f32]);
        let (hits, misses) = cache.counters();
        assert_eq!(hits, 1);
        assert!(misses == 0 && cache.hit_rate() == 1.0);
    }

    #[test]
    fn resolve_cache_respects_byte_budget() {
        let id = |i: u32| crate::store::hash_bytes(&i.to_le_bytes());
        // 100 entries allowed, but only 256 payload bytes (64 f32s).
        let cache = ResolveCache::with_max_bytes(100, 256);
        for i in 0..10u32 {
            cache.insert(id(i), vec![0.0; 16]); // 64 bytes each
        }
        assert!(cache.len() <= 4, "byte budget must cap residency");
        // A tensor bigger than the whole budget still caches (alone).
        cache.insert(id(999), vec![0.0; 1024]);
        assert!(cache.get(&id(999)).is_some());
        assert_eq!(cache.len(), 1);
    }

    /// The accounting counters exposed to `/metrics` — evictions and
    /// resident bytes — must track the cache's actual behavior.
    #[test]
    fn resolve_cache_accounting_counters() {
        let id = |i: u32| crate::store::hash_bytes(&i.to_le_bytes());
        let cache = ResolveCache::new(4);
        assert_eq!(cache.evictions(), 0);
        assert_eq!(cache.resident_bytes(), 0);
        for i in 0..4u32 {
            cache.insert(id(i), vec![i as f32; 8]); // 32 bytes each
        }
        assert_eq!(cache.evictions(), 0, "under capacity: nothing evicted");
        assert_eq!(cache.resident_bytes(), 4 * 32);
        // Each further insert evicts exactly one LRU entry; resident
        // bytes stay at capacity.
        for i in 4..10u32 {
            cache.insert(id(i), vec![i as f32; 8]);
        }
        assert_eq!(cache.evictions(), 6);
        assert_eq!(cache.resident_bytes(), 4 * 32);
        // Re-inserting a resident id is a no-op for both counters.
        cache.insert(id(9), vec![0.0; 8]);
        assert_eq!(cache.evictions(), 6);
        assert_eq!(cache.resident_bytes(), 4 * 32);
    }

    /// Eviction is LRU, not FIFO: a base tensor inserted first but hit
    /// often must outlive colder, newer entries.
    #[test]
    fn resolve_cache_keeps_recently_used_over_older_inserts() {
        let id = |i: u32| crate::store::hash_bytes(&i.to_le_bytes());
        let cache = ResolveCache::new(4);
        for i in 0..4u32 {
            cache.insert(id(i), vec![i as f32]);
        }
        // Touch the oldest entry (a "shared base"), then overflow.
        assert!(cache.get(&id(0)).is_some());
        cache.insert(id(100), vec![100.0]);
        assert!(cache.get(&id(0)).is_some(), "recently used base was evicted");
        assert!(cache.get(&id(1)).is_none(), "LRU entry must be the one evicted");
    }

    #[test]
    fn shared_cache_and_parallel_load_match_serial() {
        let zoo = big_zoo();
        let spec = zoo.arch("big").unwrap();
        let store = Store::in_memory();
        let cfg = CompressConfig::default();
        let v0 = Checkpoint::init(spec, 1);
        let (m0, _) = store_raw(&store, spec, &v0).unwrap();
        let mut prev_ck = v0;
        let mut prev_m = m0;
        for ver in 0..4u64 {
            let child = perturbed(&prev_ck, 3e-4, 50 + ver);
            let cand = prepare_delta(
                &store, spec, &child, spec, &prev_ck, &prev_m, cfg, &NativeKernel,
            )
            .unwrap();
            commit(&store, &cand).unwrap();
            prev_ck = cand.checkpoint;
            prev_m = cand.model;
        }
        let serial = load(&store, &zoo, &prev_m, &NativeKernel).unwrap();
        let cache = ResolveCache::new(64);
        let cached = load_with_cache(&store, &zoo, &prev_m, &NativeKernel, &cache).unwrap();
        assert_eq!(serial.flat, cached.flat);
        assert!(!cache.is_empty());
        let parallel =
            load_parallel(&store, &zoo, &prev_m, &NativeKernel, &cache, 4).unwrap();
        assert_eq!(serial.flat, parallel.flat);
        let (hits, _) = cache.counters();
        assert!(hits > 0, "second load must hit the shared cache");
        // Threads sharing one cache resolve concurrently to identical bits.
        std::thread::scope(|s| {
            for _ in 0..4 {
                let (store, zoo, model, cache, want) =
                    (&store, &zoo, &prev_m, &cache, &serial);
                s.spawn(move || {
                    let got =
                        load_with_cache(store, zoo, model, &NativeKernel, cache).unwrap();
                    assert_eq!(got.flat, want.flat);
                });
            }
        });
    }

    #[test]
    fn stored_model_json_roundtrip() {
        let zoo = tiny_zoo();
        let spec = zoo.arch("t0").unwrap();
        let store = Store::in_memory();
        let (m, _) = store_raw(&store, spec, &Checkpoint::init(spec, 0)).unwrap();
        let j = m.to_json();
        let back = StoredModel::from_json(&j).unwrap();
        assert_eq!(m, back);
        assert_eq!(m.refs().len(), 3);
    }

    #[test]
    fn full_baseline_sizes() {
        let zoo = big_zoo();
        let spec = zoo.arch("big").unwrap();
        let ck = Checkpoint::init(spec, 3);
        let (q_size, rec) =
            full_model_compressed_size(&ck, Codec::Deflate, 1e-4, true).unwrap();
        let (nq_size, same) =
            full_model_compressed_size(&ck, Codec::Deflate, 1e-4, false).unwrap();
        assert!(q_size > 0 && nq_size > 0);
        assert_eq!(same.flat, ck.flat);
        // quantized reconstruction within bound
        for (a, b) in rec.flat.iter().zip(&ck.flat) {
            assert!((a - b).abs() <= quant::step(1e-4) * 1.001);
        }
    }
}
