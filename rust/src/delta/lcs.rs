//! Longest-common-subsequence matching between two models' parameter
//! layouts (paper §4): parent and child may have *different architectures*,
//! so deltas are only computed between parameters matched by an LCS over
//! their shape sequences. For identical architectures this reduces to the
//! identity mapping of corresponding layers.
//!
//! Invariant: the returned pairs are strictly increasing in *both*
//! coordinates (a valid common subsequence — matches never cross) and
//! each pair's keys compare equal; for identical sequences every index
//! maps to itself.
//!
//! ```
//! use mgit::delta::lcs::lcs_pairs;
//!
//! assert_eq!(lcs_pairs(&[1, 2, 3], &[2, 3, 4]), vec![(1, 0), (2, 1)]);
//! assert_eq!(lcs_pairs(&[7, 8], &[7, 8]), vec![(0, 0), (1, 1)]);
//! ```

use crate::checkpoint::ParamEntry;

/// Matched (parent_index, child_index) pairs, strictly increasing in both
/// coordinates, with equal shapes within each pair.
pub fn match_params(parent: &[ParamEntry], child: &[ParamEntry]) -> Vec<(usize, usize)> {
    lcs_pairs(
        &parent.iter().map(|e| shape_key(e)).collect::<Vec<_>>(),
        &child.iter().map(|e| shape_key(e)).collect::<Vec<_>>(),
    )
}

fn shape_key(e: &ParamEntry) -> String {
    format!("{:?}", e.shape)
}

/// Classic O(n·m) DP LCS over arbitrary equatable keys, returning the
/// matched index pairs.
pub fn lcs_pairs<T: PartialEq>(a: &[T], b: &[T]) -> Vec<(usize, usize)> {
    let (n, m) = (a.len(), b.len());
    if n == 0 || m == 0 {
        return Vec::new();
    }
    // dp[i][j] = LCS length of a[i..], b[j..]
    let mut dp = vec![vec![0u32; m + 1]; n + 1];
    for i in (0..n).rev() {
        for j in (0..m).rev() {
            dp[i][j] = if a[i] == b[j] {
                dp[i + 1][j + 1] + 1
            } else {
                dp[i + 1][j].max(dp[i][j + 1])
            };
        }
    }
    let mut out = Vec::with_capacity(dp[0][0] as usize);
    let (mut i, mut j) = (0, 0);
    while i < n && j < m {
        if a[i] == b[j] {
            out.push((i, j));
            i += 1;
            j += 1;
        } else if dp[i + 1][j] >= dp[i][j + 1] {
            i += 1;
        } else {
            j += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gen, prop_assert};

    #[test]
    fn identical_sequences_match_fully() {
        let a = vec!["x", "y", "z"];
        let pairs = lcs_pairs(&a, &a);
        assert_eq!(pairs, vec![(0, 0), (1, 1), (2, 2)]);
    }

    #[test]
    fn classic_example() {
        let a: Vec<char> = "ABCBDAB".chars().collect();
        let b: Vec<char> = "BDCABA".chars().collect();
        let pairs = lcs_pairs(&a, &b);
        assert_eq!(pairs.len(), 4); // e.g. BCAB / BDAB
        for w in pairs.windows(2) {
            assert!(w[0].0 < w[1].0 && w[0].1 < w[1].1);
        }
        for &(i, j) in &pairs {
            assert_eq!(a[i], b[j]);
        }
    }

    #[test]
    fn empty_inputs() {
        let empty: Vec<u8> = vec![];
        assert!(lcs_pairs(&empty, &[1u8, 2]).is_empty());
        assert!(lcs_pairs(&[1u8, 2], &empty).is_empty());
    }

    /// Oracle: LCS length via a second, recursive implementation on tiny
    /// inputs.
    fn lcs_len_oracle<T: PartialEq>(a: &[T], b: &[T]) -> usize {
        if a.is_empty() || b.is_empty() {
            0
        } else if a[0] == b[0] {
            1 + lcs_len_oracle(&a[1..], &b[1..])
        } else {
            lcs_len_oracle(&a[1..], b).max(lcs_len_oracle(a, &b[1..]))
        }
    }

    #[test]
    fn prop_valid_and_maximal() {
        check("lcs valid & maximal", 120, |rng, _b| {
            let n = rng.usize_below(9);
            let m = rng.usize_below(9);
            let a = gen::vec_u8(rng, n).iter().map(|x| x % 4).collect::<Vec<_>>();
            let b = gen::vec_u8(rng, m).iter().map(|x| x % 4).collect::<Vec<_>>();
            let pairs = lcs_pairs(&a, &b);
            // valid: strictly increasing and equal elements
            for w in pairs.windows(2) {
                prop_assert(w[0].0 < w[1].0 && w[0].1 < w[1].1, "not increasing")?;
            }
            for &(i, j) in &pairs {
                prop_assert(a[i] == b[j], "pair elements differ")?;
            }
            // maximal: matches the oracle length
            prop_assert(
                pairs.len() == lcs_len_oracle(&a, &b),
                format!("len {} != oracle", pairs.len()),
            )
        });
    }

    #[test]
    fn param_matching_same_arch_is_identity() {
        let zoo = crate::checkpoint::testutil::tiny_zoo();
        let spec = zoo.arch("t0").unwrap();
        let pairs = match_params(&spec.layout, &spec.layout);
        assert_eq!(pairs.len(), spec.layout.len());
        assert!(pairs.iter().all(|&(i, j)| i == j));
    }

    #[test]
    fn param_matching_cross_arch_uses_shapes() {
        let zoo = crate::checkpoint::testutil::tiny_zoo();
        let t0 = zoo.arch("t0").unwrap(); // shapes [2,3],[4],[4]
        let t1 = zoo.arch("t1").unwrap(); // shapes [2,3],[6]
        let pairs = match_params(&t0.layout, &t1.layout);
        assert_eq!(pairs, vec![(0, 0)]); // only the [2,3] tensors match
    }
}
