//! Lossless codecs for quantized deltas.
//!
//! The paper evaluates RLE and LZMA; LZMA is not in the offline crate set,
//! so DEFLATE (zlib) stands in as the "slow, high-ratio dictionary codec"
//! and zstd is provided as an ablation point (see DESIGN.md §2). Codec ids
//! are persisted inside MGTF objects — do not renumber.
//!
//! Invariants: `decompress(compress(x), x.len()) == x` for every codec
//! and every byte string (lossless by contract — the delta pipeline's
//! bit-exactness depends on it), and `decompress` fails rather than
//! return data of the wrong length.
//!
//! ```
//! use mgit::delta::Codec;
//!
//! let data: Vec<u8> = (0..100u8).flat_map(|b| [b, 0, 0, 0]).collect();
//! let packed = Codec::Rle.compress(&data).unwrap();
//! assert_eq!(Codec::Rle.decompress(&packed, data.len()).unwrap(), data);
//! // persisted ids round-trip and never change
//! assert_eq!(Codec::from_code(Codec::Rle.code()).unwrap(), Codec::Rle);
//! ```

use std::io::{Read, Write};

use anyhow::{anyhow, bail, Result};

use super::rle;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Codec {
    /// PackBits run-length coding (paper's RLE).
    Rle,
    /// DEFLATE/zlib (stands in for the paper's LZMA).
    Deflate,
    /// zstd (ablation). The variant always exists — codec ids are
    /// persisted, so decoding must be able to *name* it — but actually
    /// compressing/decompressing with it needs the feature-gated `zstd`
    /// dependency (`--features zstd`); without it both operations return
    /// a descriptive error.
    Zstd,
}

impl Codec {
    pub fn code(self) -> u8 {
        match self {
            Codec::Rle => 0,
            Codec::Deflate => 1,
            Codec::Zstd => 2,
        }
    }

    pub fn from_code(c: u8) -> Result<Codec> {
        match c {
            0 => Ok(Codec::Rle),
            1 => Ok(Codec::Deflate),
            2 => Ok(Codec::Zstd),
            _ => bail!("unknown codec code {c}"),
        }
    }

    /// Parse a user-facing name. `lzma` is accepted as an alias for the
    /// dictionary codec to keep the paper's configuration names usable.
    pub fn parse(name: &str) -> Result<Codec> {
        match name.to_ascii_lowercase().as_str() {
            "rle" => Ok(Codec::Rle),
            "deflate" | "zlib" | "lzma" => Ok(Codec::Deflate),
            "zstd" => Ok(Codec::Zstd),
            other => Err(anyhow!("unknown codec `{other}` (rle|deflate|zstd)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Codec::Rle => "rle",
            Codec::Deflate => "deflate",
            Codec::Zstd => "zstd",
        }
    }

    pub fn compress(self, data: &[u8]) -> Result<Vec<u8>> {
        match self {
            Codec::Rle => Ok(rle::encode(data)),
            Codec::Deflate => {
                let mut enc = flate2::write::ZlibEncoder::new(
                    Vec::new(),
                    flate2::Compression::new(6),
                );
                enc.write_all(data)?;
                Ok(enc.finish()?)
            }
            #[cfg(feature = "zstd")]
            Codec::Zstd => Ok(zstd::bulk::compress(data, 6)?),
            #[cfg(not(feature = "zstd"))]
            Codec::Zstd => Err(no_zstd()),
        }
    }

    /// `expected_len` is the decompressed size (known from the MGTF header).
    pub fn decompress(self, data: &[u8], expected_len: usize) -> Result<Vec<u8>> {
        let out = match self {
            Codec::Rle => rle::decode(data)?,
            Codec::Deflate => {
                let mut dec = flate2::read::ZlibDecoder::new(data);
                let mut out = Vec::with_capacity(expected_len);
                dec.read_to_end(&mut out)?;
                out
            }
            #[cfg(feature = "zstd")]
            Codec::Zstd => zstd::bulk::decompress(data, expected_len.max(1))?,
            #[cfg(not(feature = "zstd"))]
            Codec::Zstd => return Err(no_zstd()),
        };
        if out.len() != expected_len {
            bail!(
                "codec {} produced {} bytes, expected {}",
                self.name(),
                out.len(),
                expected_len
            );
        }
        Ok(out)
    }
}

#[cfg(not(feature = "zstd"))]
fn no_zstd() -> anyhow::Error {
    anyhow!(
        "the zstd codec is not compiled into this build \
         (rebuild with --features zstd)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gen, prop_assert};

    /// Codecs usable for actual (de)compression in this build. `Zstd`
    /// always names/parses (ids are persisted) but only compresses with
    /// the `zstd` feature.
    #[cfg(feature = "zstd")]
    const ALL: [Codec; 3] = [Codec::Rle, Codec::Deflate, Codec::Zstd];
    #[cfg(not(feature = "zstd"))]
    const ALL: [Codec; 2] = [Codec::Rle, Codec::Deflate];

    #[test]
    fn codes_roundtrip() {
        for c in [Codec::Rle, Codec::Deflate, Codec::Zstd] {
            assert_eq!(Codec::from_code(c.code()).unwrap(), c);
            assert_eq!(Codec::parse(c.name()).unwrap(), c);
        }
        assert_eq!(Codec::parse("LZMA").unwrap(), Codec::Deflate);
        assert!(Codec::parse("brotli").is_err());
        assert!(Codec::from_code(9).is_err());
    }

    #[cfg(not(feature = "zstd"))]
    #[test]
    fn zstd_codec_errors_without_feature() {
        let err = Codec::Zstd.compress(b"data").unwrap_err().to_string();
        assert!(err.contains("--features zstd"), "got: {err}");
        assert!(Codec::Zstd.decompress(b"data", 4).is_err());
    }

    #[test]
    fn all_codecs_roundtrip_sparse_deltas() {
        // Typical payload: i32 deltas, mostly zero.
        let mut data = vec![0u8; 64 * 1024];
        for i in (0..data.len()).step_by(97) {
            data[i] = (i % 251) as u8;
        }
        for c in ALL {
            let enc = c.compress(&data).unwrap();
            assert!(enc.len() < data.len(), "{} did not compress", c.name());
            assert_eq!(c.decompress(&enc, data.len()).unwrap(), data);
        }
    }

    #[test]
    fn wrong_expected_len_detected() {
        let data = vec![7u8; 100];
        for c in ALL {
            let enc = c.compress(&data).unwrap();
            assert!(c.decompress(&enc, 99).is_err());
        }
    }

    #[test]
    fn prop_all_codecs_roundtrip() {
        check("codec roundtrip", 60, |rng, b| {
            let n = gen::len(rng, b);
            let data = gen::vec_u8_runs(rng, n);
            for c in ALL {
                let enc = c.compress(&data).map_err(|e| e.to_string())?;
                let dec = c.decompress(&enc, data.len()).map_err(|e| e.to_string())?;
                prop_assert(dec == data, format!("{} roundtrip", c.name()))?;
            }
            Ok(())
        });
    }
}
