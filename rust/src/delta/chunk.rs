//! Content-defined chunking (CDC) over raw tensor bytes.
//!
//! Splits a byte stream into variable-sized chunks whose boundaries are
//! decided by a Gear rolling hash over the *content*, not by fixed
//! offsets. Inserting or deleting a byte therefore shifts boundaries
//! only locally: the hash resynchronises within one chunk, and every
//! chunk after the edit keeps its fingerprint. That resilience is what
//! lets the pack writer dedup byte ranges shared across *unrelated*
//! objects (no lineage edge required) — see
//! [`crate::store::pack::recipe`] and `docs/COMPRESSION.md`.
//!
//! Invariants:
//!
//! * **Deterministic.** The gear table is a compile-time constant
//!   (splitmix64-filled), so the same bytes under the same
//!   [`ChunkConfig`] always produce the same chunk list — across runs,
//!   platforms and versions. Fingerprints are SHA-256 over the chunk
//!   bytes, matching the store's content-addressing hash.
//! * **Bounded.** Every chunk length `l` satisfies
//!   `min ≤ l ≤ max`, except the final chunk which may be shorter than
//!   `min`. Expected length is `min + 2^avg_bits`.
//! * **Complete.** Chunks tile the input exactly: they are contiguous,
//!   non-overlapping, and their lengths sum to the input length.
//!
//! ```
//! use mgit::delta::chunk::{chunk_bytes, ChunkConfig};
//!
//! let data = vec![42u8; 10_000];
//! let a = chunk_bytes(&data, &ChunkConfig::default());
//! let b = chunk_bytes(&data, &ChunkConfig::default());
//! assert_eq!(a, b); // fully deterministic
//! // chunks tile the input exactly
//! assert_eq!(a.iter().map(|c| c.len as usize).sum::<usize>(), data.len());
//! ```

use sha2::{Digest, Sha256};

/// Chunking bounds. The defaults (64 B min, 512 B average target,
/// 4 KiB max) are tuned for f32 tensor payloads: fine enough that a
/// shared sub-tensor region spans several chunks, coarse enough that
/// per-chunk bookkeeping (32-byte fingerprint + 13-byte copy op) stays
/// well under 10% of the data it describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkConfig {
    /// Minimum chunk length in bytes; boundaries are not considered
    /// before this many bytes have been consumed.
    pub min: usize,
    /// Boundary mask width: a boundary fires when the low `avg_bits`
    /// bits of the rolling hash are zero, giving an expected chunk
    /// length of `min + 2^avg_bits`.
    pub avg_bits: u32,
    /// Hard cap: a boundary is forced at this length even if the hash
    /// never fires (e.g. on constant input).
    pub max: usize,
}

impl Default for ChunkConfig {
    fn default() -> ChunkConfig {
        ChunkConfig { min: 64, avg_bits: 9, max: 4096 }
    }
}

/// One chunk of the input: its position, length and content
/// fingerprint (SHA-256 of the chunk bytes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunk {
    /// Byte offset of the chunk within the input.
    pub start: usize,
    /// Chunk length in bytes.
    pub len: u32,
    /// SHA-256 of the chunk bytes.
    pub hash: [u8; 32],
}

/// splitmix64 step — the standard 64-bit finalizer used to fill the
/// gear table deterministically at compile time.
const fn splitmix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Per-byte gear values. `h = (h << 1) + GEAR[b]` gives every input
/// byte a ~64-byte window of influence (after 64 shifts a byte's
/// contribution has left the accumulator), which is what makes the
/// chunker resynchronise after an insert or delete.
const GEAR: [u64; 256] = {
    let mut t = [0u64; 256];
    let mut i = 0;
    while i < 256 {
        t[i] = splitmix64(0x6D67_6974_2D63_6463 ^ (i as u64)); // "mgit-cdc"
        i += 1;
    }
    t
};

/// Split `data` into content-defined chunks under `cfg`.
///
/// Returns chunks in input order; see the module docs for the
/// determinism / bounds / tiling invariants. Empty input yields an
/// empty list.
pub fn chunk_bytes(data: &[u8], cfg: &ChunkConfig) -> Vec<Chunk> {
    let min = cfg.min.max(1);
    let max = cfg.max.max(min);
    let mask: u64 = (1u64 << cfg.avg_bits.min(63)) - 1;
    let mut chunks = Vec::new();
    let mut start = 0usize;
    let mut h = 0u64;
    for (pos, &b) in data.iter().enumerate() {
        h = (h << 1).wrapping_add(GEAR[b as usize]);
        let filled = pos + 1 - start;
        if (filled >= min && (h & mask) == 0) || filled >= max {
            chunks.push(fingerprint(data, start, pos + 1));
            start = pos + 1;
            h = 0;
        }
    }
    if start < data.len() {
        chunks.push(fingerprint(data, start, data.len()));
    }
    chunks
}

fn fingerprint(data: &[u8], start: usize, end: usize) -> Chunk {
    let mut h = Sha256::new();
    h.update(&data[start..end]);
    Chunk { start, len: (end - start) as u32, hash: h.finalize().into() }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic byte stream with enough entropy that gear
    /// boundaries actually fire.
    fn noise(n: usize, seed: u64) -> Vec<u8> {
        let mut out = Vec::with_capacity(n);
        let mut s = seed;
        while out.len() < n {
            s = splitmix64(s);
            out.extend_from_slice(&s.to_le_bytes());
        }
        out.truncate(n);
        out
    }

    #[test]
    fn chunks_tile_input_and_respect_bounds() {
        let cfg = ChunkConfig::default();
        let data = noise(64 * 1024, 7);
        let chunks = chunk_bytes(&data, &cfg);
        assert!(chunks.len() > 32, "expected many chunks, got {}", chunks.len());
        let mut pos = 0usize;
        for (i, c) in chunks.iter().enumerate() {
            assert_eq!(c.start, pos, "chunk {i} not contiguous");
            let last = i + 1 == chunks.len();
            assert!(c.len as usize <= cfg.max);
            if !last {
                assert!(c.len as usize >= cfg.min, "chunk {i} under min");
            }
            pos += c.len as usize;
        }
        assert_eq!(pos, data.len());
    }

    #[test]
    fn determinism_across_calls() {
        let cfg = ChunkConfig::default();
        let data = noise(16 * 1024, 99);
        assert_eq!(chunk_bytes(&data, &cfg), chunk_bytes(&data, &cfg));
    }

    #[test]
    fn constant_input_forces_max_size_chunks() {
        let cfg = ChunkConfig::default();
        let data = vec![0u8; 3 * cfg.max + 100];
        let chunks = chunk_bytes(&data, &cfg);
        assert_eq!(chunks.len(), 4);
        for c in &chunks[..3] {
            assert_eq!(c.len as usize, cfg.max);
        }
        assert_eq!(chunks[3].len as usize, 100);
        // identical content => identical fingerprints
        assert_eq!(chunks[0].hash, chunks[1].hash);
    }

    #[test]
    fn boundary_shift_resilience_on_insert() {
        // Insert one byte mid-stream: boundaries resynchronise, so the
        // overwhelming majority of chunk fingerprints survive.
        let cfg = ChunkConfig::default();
        let data = noise(64 * 1024, 1234);
        let mut edited = data.clone();
        edited.insert(data.len() / 3, 0xA5);

        let a: std::collections::HashSet<[u8; 32]> =
            chunk_bytes(&data, &cfg).iter().map(|c| c.hash).collect();
        let b: std::collections::HashSet<[u8; 32]> =
            chunk_bytes(&edited, &cfg).iter().map(|c| c.hash).collect();
        let common = a.intersection(&b).count();
        assert!(
            common * 2 > a.len(),
            "only {common} of {} chunks survived a 1-byte insert",
            a.len()
        );
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let cfg = ChunkConfig::default();
        assert!(chunk_bytes(&[], &cfg).is_empty());
        let one = chunk_bytes(&[7u8], &cfg);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].len, 1);
    }
}
