//! Native (CPU) reference implementation of the delta quantizer.
//!
//! The formula is the paper's (§4, following Hu et al. 2020):
//!
//! ```text
//! Δp = p_parent − p_child
//! Δp_quantized = floor(Δp / (2·ln(1+ε)) + 0.5)
//! ```
//!
//! The hot path runs the AOT-compiled Pallas kernel through PJRT
//! ([`crate::runtime::Runtime`] implements [`DeltaKernel`] too); this
//! native version is the oracle and the fallback when no artifacts are
//! present (pure-storage unit tests, property tests).
//!
//! Guarantee: `|Δp − q·step| ≤ step/2 = ln(1+ε)` for all finite inputs
//! within i32 range, which bounds the per-element reconstruction error.
//!
//! ```
//! use mgit::delta::{DeltaKernel, NativeKernel};
//! use mgit::delta::quant::step;
//!
//! let parent = vec![1.0f32, 2.0, -3.0];
//! let child = vec![1.5f32, 1.875, -3.25];
//! let eps = 1e-3f32;
//! let q = NativeKernel.quantize(&parent, &child, eps).unwrap();
//! let rec = NativeKernel.dequantize(&parent, &q, eps).unwrap();
//! for (r, c) in rec.iter().zip(&child) {
//!     assert!((r - c).abs() <= step(eps)); // within the error bound
//! }
//! ```

use anyhow::Result;

/// Backend-agnostic quantization interface (native or PJRT kernel).
pub trait DeltaKernel {
    fn quantize(&self, parent: &[f32], child: &[f32], eps: f32) -> Result<Vec<i32>>;
    fn dequantize(&self, parent: &[f32], q: &[i32], eps: f32) -> Result<Vec<f32>>;
}

/// Quantization step for a given error bound.
pub fn step(eps: f32) -> f32 {
    2.0 * (1.0 + eps).ln()
}

/// Pure-Rust kernel (bit-compatible with the Pallas kernel's math).
pub struct NativeKernel;

impl DeltaKernel for NativeKernel {
    fn quantize(&self, parent: &[f32], child: &[f32], eps: f32) -> Result<Vec<i32>> {
        anyhow::ensure!(parent.len() == child.len(), "length mismatch");
        let s = step(eps);
        Ok(parent
            .iter()
            .zip(child)
            .map(|(&p, &c)| ((p - c) / s + 0.5).floor() as i32)
            .collect())
    }

    fn dequantize(&self, parent: &[f32], q: &[i32], eps: f32) -> Result<Vec<f32>> {
        anyhow::ensure!(parent.len() == q.len(), "length mismatch");
        let s = step(eps);
        Ok(parent
            .iter()
            .zip(q)
            .map(|(&p, &qi)| p - qi as f32 * s)
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gen, prop_assert};

    #[test]
    fn zero_delta_quantizes_to_zero() {
        let v = vec![1.0f32, -2.0, 0.0, 3.5];
        let q = NativeKernel.quantize(&v, &v, 1e-4).unwrap();
        assert!(q.iter().all(|&x| x == 0));
        let back = NativeKernel.dequantize(&v, &q, 1e-4).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn error_bound_holds() {
        let eps = 1e-4f32;
        let parent = vec![0.5f32, -0.25, 1.0, 2.0];
        let child = vec![0.5003f32, -0.2504, 0.9991, 2.0002];
        let q = NativeKernel.quantize(&parent, &child, eps).unwrap();
        let rec = NativeKernel.dequantize(&parent, &q, eps).unwrap();
        for (r, c) in rec.iter().zip(&child) {
            assert!((r - c).abs() <= step(eps), "err {}", (r - c).abs());
        }
    }

    #[test]
    fn larger_eps_zeroes_more() {
        let mut rng = crate::util::rng::Rng::new(3);
        let parent: Vec<f32> = (0..1000).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let child: Vec<f32> = parent.iter().map(|&p| p + rng.normal_f32(0.0, 1e-4)).collect();
        let q_small = NativeKernel.quantize(&parent, &child, 1e-5).unwrap();
        let q_large = NativeKernel.quantize(&parent, &child, 1e-3).unwrap();
        let nz = |q: &[i32]| q.iter().filter(|&&x| x != 0).count();
        assert!(nz(&q_large) < nz(&q_small));
    }

    #[test]
    fn length_mismatch_rejected() {
        assert!(NativeKernel.quantize(&[1.0], &[1.0, 2.0], 1e-4).is_err());
        assert!(NativeKernel.dequantize(&[1.0], &[1, 2], 1e-4).is_err());
    }

    #[test]
    fn prop_error_bound() {
        check("quantize error bound", 100, |rng, b| {
            let n = 1 + gen::len(rng, b);
            let eps = [1e-5f32, 1e-4, 1e-3][rng.usize_below(3)];
            let parent = gen::vec_f32(rng, n, 1.0);
            let noise = gen::vec_f32(rng, n, 0.01);
            let child: Vec<f32> =
                parent.iter().zip(&noise).map(|(&p, &d)| p + d).collect();
            let q = NativeKernel.quantize(&parent, &child, eps).unwrap();
            let rec = NativeKernel.dequantize(&parent, &q, eps).unwrap();
            let bound = step(eps) * (1.0 + 1e-4); // small f32 slack
            for (r, c) in rec.iter().zip(&child) {
                prop_assert(
                    (r - c).abs() <= bound,
                    format!("err {} > bound {}", (r - c).abs(), bound),
                )?;
            }
            Ok(())
        });
    }
}
