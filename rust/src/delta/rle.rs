//! Byte-oriented run-length coding (PackBits-style), the paper's "RLE"
//! lossless codec option (Robinson & Cherry, 1967).
//!
//! Control byte `c`:
//! * `c < 128`  — literal run: copy the next `c + 1` bytes verbatim,
//! * `c >= 128` — repeat run: repeat the next byte `c - 126` times
//!   (runs of 2..=129).
//!
//! Quantized deltas are dominated by zero bytes, RLE's best case; worst
//! case expansion on incompressible data is 1/128 overhead.
//!
//! Invariant: `decode(encode(x)) == x` for every byte string, and
//! `decode` rejects truncated input instead of producing partial
//! output.
//!
//! ```
//! let zeros = vec![0u8; 1024];
//! let enc = mgit::delta::rle::encode(&zeros);
//! assert!(enc.len() < zeros.len() / 16); // long runs collapse
//! assert_eq!(mgit::delta::rle::decode(&enc).unwrap(), zeros);
//! ```

use anyhow::{bail, Result};

pub fn encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() / 4 + 16);
    let mut i = 0;
    while i < data.len() {
        // Measure the run starting at i.
        let b = data[i];
        let mut run = 1;
        while i + run < data.len() && data[i + run] == b && run < 129 {
            run += 1;
        }
        if run >= 2 {
            out.push(126 + run as u8); // 128..=255 encodes runs 2..=129
            out.push(b);
            i += run;
        } else {
            // Collect literals until the next run of >= 3 (a run of 2 is
            // not worth breaking a literal for) or the 128-byte cap.
            let start = i;
            i += 1;
            while i < data.len() && (i - start) < 128 {
                let b = data[i];
                let mut r = 1;
                while i + r < data.len() && data[i + r] == b && r < 3 {
                    r += 1;
                }
                if r >= 3 {
                    break;
                }
                i += 1;
            }
            let len = i - start;
            out.push((len - 1) as u8);
            out.extend_from_slice(&data[start..i]);
        }
    }
    out
}

pub fn decode(data: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(data.len() * 2);
    let mut i = 0;
    while i < data.len() {
        let c = data[i];
        i += 1;
        if c < 128 {
            let n = c as usize + 1;
            if i + n > data.len() {
                bail!("truncated RLE literal run");
            }
            out.extend_from_slice(&data[i..i + n]);
            i += n;
        } else {
            if i >= data.len() {
                bail!("truncated RLE repeat run");
            }
            let n = c as usize - 126;
            let b = data[i];
            i += 1;
            out.resize(out.len() + n, b);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, gen, prop_assert};

    #[test]
    fn empty() {
        assert_eq!(encode(&[]), Vec::<u8>::new());
        assert_eq!(decode(&[]).unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn zeros_compress_well() {
        let data = vec![0u8; 10_000];
        let enc = encode(&data);
        assert!(enc.len() < data.len() / 50, "enc={}", enc.len());
        assert_eq!(decode(&enc).unwrap(), data);
    }

    #[test]
    fn worst_case_bounded() {
        let data: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        let enc = encode(&data);
        assert!(enc.len() <= data.len() + data.len() / 128 + 2);
        assert_eq!(decode(&enc).unwrap(), data);
    }

    #[test]
    fn truncated_input_rejected() {
        assert!(decode(&[5, 1, 2]).is_err()); // literal run of 6, only 2 bytes
        assert!(decode(&[200]).is_err()); // repeat run missing its byte
    }

    #[test]
    fn prop_roundtrip_random() {
        check("rle roundtrip random bytes", 150, |rng, b| {
            let n = gen::len(rng, b);
            let data = gen::vec_u8(rng, n);
            let back = decode(&encode(&data)).map_err(|e| e.to_string())?;
            prop_assert(back == data, "roundtrip mismatch")
        });
    }

    #[test]
    fn prop_roundtrip_runs() {
        check("rle roundtrip runny bytes", 150, |rng, b| {
            let n = gen::len(rng, b);
            let data = gen::vec_u8_runs(rng, n);
            let enc = encode(&data);
            let back = decode(&enc).map_err(|e| e.to_string())?;
            prop_assert(back == data, "roundtrip mismatch")
        });
    }
}
