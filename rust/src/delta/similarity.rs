//! Min-hash similarity sketches over chunk fingerprints.
//!
//! A [`Sketch`] is a bottom-k min-hash of an object's
//! [chunk](crate::delta::chunk) fingerprint set: the `k` smallest
//! 64-bit keys drawn from the chunk hashes. Two sketches estimate the
//! Jaccard similarity of the underlying chunk sets without touching
//! the data — O(k) memory per object, O(k) comparison time — which is
//! what lets the repacker rank *every* previously-packed object as a
//! candidate delta base in one pass (`repack --similarity`, see
//! `docs/COMPRESSION.md`).
//!
//! Invariants:
//!
//! * **Deterministic.** Keys are the first 8 bytes of each SHA-256
//!   chunk fingerprint (already uniform), so the same chunk set always
//!   yields the same sketch.
//! * **Mergeable estimate.** `similarity(a, b)` is the classic
//!   bottom-k estimator: the fraction of the k smallest keys of
//!   `A ∪ B` that appear in both sets. It is symmetric, in `[0, 1]`,
//!   exactly 1.0 for identical non-empty sets and 0.0 for disjoint
//!   ones.
//!
//! ```
//! use mgit::delta::chunk::{chunk_bytes, ChunkConfig};
//! use mgit::delta::similarity::Sketch;
//!
//! let cfg = ChunkConfig::default();
//! let base: Vec<u8> = (0..20_000u32).flat_map(|i| i.to_le_bytes()).collect();
//! let mut near = base.clone();
//! near[0] ^= 0xFF; // a single byte differs
//! let far = vec![9u8; 20_000]; // unrelated content
//!
//! let a = Sketch::of_chunks(&chunk_bytes(&base, &cfg));
//! let b = Sketch::of_chunks(&chunk_bytes(&near, &cfg));
//! let c = Sketch::of_chunks(&chunk_bytes(&far, &cfg));
//! assert_eq!(a.similarity(&a), 1.0);
//! assert!(a.similarity(&b) > a.similarity(&c));
//! ```

use super::chunk::Chunk;

/// Sketch size: the `k` in bottom-k. 16 keys give a Jaccard estimate
/// with standard error ≈ 1/√k ≈ 0.25 — coarse, but base selection only
/// needs to *rank* candidates and gate on a threshold, and every
/// candidate that passes is verified bit-exactly before use.
pub const SKETCH_K: usize = 16;

/// Bottom-k min-hash sketch of a chunk fingerprint set.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Sketch {
    /// The k smallest distinct keys, sorted ascending.
    keys: Vec<u64>,
}

impl Sketch {
    /// Sketch a chunk list (as produced by
    /// [`chunk_bytes`](crate::delta::chunk::chunk_bytes)).
    pub fn of_chunks(chunks: &[Chunk]) -> Sketch {
        Sketch::from_keys(chunks.iter().map(|c| {
            u64::from_le_bytes([
                c.hash[0], c.hash[1], c.hash[2], c.hash[3], c.hash[4], c.hash[5], c.hash[6],
                c.hash[7],
            ])
        }))
    }

    /// Sketch an arbitrary key stream (already uniformly distributed).
    pub fn from_keys(keys: impl IntoIterator<Item = u64>) -> Sketch {
        let mut all: Vec<u64> = keys.into_iter().collect();
        all.sort_unstable();
        all.dedup();
        all.truncate(SKETCH_K);
        Sketch { keys: all }
    }

    /// Number of keys retained (`min(k, distinct chunks)`).
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// True when the sketched chunk set was empty.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Bottom-k Jaccard similarity estimate in `[0, 1]`.
    ///
    /// Merges the two sorted key lists, keeps the k smallest distinct
    /// keys of the union, and returns the fraction present in both
    /// sketches. Empty-vs-anything compares as 0.0.
    pub fn similarity(&self, other: &Sketch) -> f64 {
        if self.keys.is_empty() || other.keys.is_empty() {
            return 0.0;
        }
        let k = SKETCH_K.min(self.keys.len().max(other.keys.len()));
        let (mut i, mut j) = (0usize, 0usize);
        let (mut taken, mut both) = (0usize, 0usize);
        while taken < k && (i < self.keys.len() || j < other.keys.len()) {
            let a = self.keys.get(i).copied();
            let b = other.keys.get(j).copied();
            match (a, b) {
                (Some(x), Some(y)) if x == y => {
                    both += 1;
                    i += 1;
                    j += 1;
                }
                (Some(x), Some(y)) if x < y => i += 1,
                (Some(_), Some(_)) => j += 1,
                (Some(_), None) => i += 1,
                (None, Some(_)) => j += 1,
                (None, None) => break,
            }
            taken += 1;
        }
        if taken == 0 {
            return 0.0;
        }
        both as f64 / taken as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_sets_score_one() {
        let s = Sketch::from_keys(1..=100u64);
        assert_eq!(s.similarity(&s), 1.0);
    }

    #[test]
    fn disjoint_sets_score_zero() {
        let a = Sketch::from_keys((0..100u64).map(|i| i * 2));
        let b = Sketch::from_keys((0..100u64).map(|i| i * 2 + 1));
        assert_eq!(a.similarity(&b), 0.0);
    }

    #[test]
    fn empty_scores_zero() {
        let e = Sketch::from_keys(std::iter::empty());
        let s = Sketch::from_keys(1..=10u64);
        assert!(e.is_empty());
        assert_eq!(e.similarity(&s), 0.0);
        assert_eq!(e.similarity(&e), 0.0);
    }

    #[test]
    fn overlap_ranks_monotonically() {
        // 75% overlap must score higher than 25% overlap against the
        // same reference.
        let base = Sketch::from_keys(0..64u64);
        let hi = Sketch::from_keys((0..48u64).chain(1000..1016));
        let lo = Sketch::from_keys((0..16u64).chain(1000..1048));
        assert!(base.similarity(&hi) > base.similarity(&lo));
        // symmetry
        assert_eq!(base.similarity(&hi), hi.similarity(&base));
    }

    #[test]
    fn dedup_and_truncation() {
        let s = Sketch::from_keys([5u64, 5, 5, 1, 2, 2].into_iter());
        assert_eq!(s.len(), 3);
        let big = Sketch::from_keys(0..10_000u64);
        assert_eq!(big.len(), SKETCH_K);
    }
}
