//! Git-style command-line parsing (`mgit <command> [positional…] [--flags]`).
//!
//! `clap` is unavailable offline; this covers what the MGit CLI needs:
//! one subcommand, positional arguments, `--key value` / `--key=value`
//! flags, and bare boolean flags. A bare flag followed by a positional
//! would consume it greedily, so boolean flags go last or use `--flag=true`.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    /// Parse `argv` (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut args = Args { command, ..Default::default() };
        while let Some(tok) = it.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                if flag.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = flag.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.flags.insert(flag.to_string(), it.next().unwrap());
                } else {
                    args.flags.insert(flag.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn pos(&self, i: usize, what: &str) -> Result<&str> {
        self.positional
            .get(i)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("missing argument <{what}> (position {i})"))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn flag_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("flag --{name} expects an integer, got `{v}`")),
        }
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("flag --{name} expects a number, got `{v}`")),
        }
    }

    pub fn flag_u64(&self, name: &str, default: u64) -> Result<u64> {
        Ok(self.flag_usize(name, default as usize)? as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("diff modelA modelB");
        assert_eq!(a.command, "diff");
        assert_eq!(a.pos(0, "a").unwrap(), "modelA");
        assert_eq!(a.pos(1, "b").unwrap(), "modelB");
        assert!(a.pos(2, "c").is_err());
    }

    #[test]
    fn flags_all_forms() {
        let a = parse("compress g2 --codec lzma --eps=1e-4 --verbose");
        assert_eq!(a.flag("codec"), Some("lzma"));
        assert_eq!(a.flag_f64("eps", 0.0).unwrap(), 1e-4);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["g2"]);
        assert_eq!(a.flag_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --n abc");
        assert!(a.flag_usize("n", 0).is_err());
    }
}
