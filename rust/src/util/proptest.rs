//! Minimal property-based testing harness (the `proptest` crate is not in
//! the offline set).
//!
//! Usage:
//! ```ignore
//! check("rle roundtrip", 200, |rng, case| {
//!     let data = gen::vec_u8(rng, 0..2048);
//!     let enc = rle_encode(&data);
//!     prop_assert(rle_decode(&enc)? == data, "roundtrip mismatch")
//! });
//! ```
//! Each case gets a deterministic per-case RNG derived from the property
//! name, so failures print a reproducible `(name, case)` pair. On failure
//! the harness retries with the *smallest* generator budget ("shrink-lite"):
//! generators consult [`Budget`] so a failing property is re-searched at
//! smaller sizes first and the minimal failing size is reported.

use super::rng::Rng;

/// Generator size budget: generators should scale their output by `size`.
#[derive(Debug, Clone, Copy)]
pub struct Budget {
    pub size: usize,
}

pub type PropResult = Result<(), String>;

pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

fn seed_for(name: &str, case: usize) -> u64 {
    // FNV-1a over the name, mixed with the case index.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15)
}

/// Run `cases` random cases of the property; panic with a reproducible
/// report on the first failure, after re-searching smaller sizes.
pub fn check(name: &str, cases: usize, prop: impl Fn(&mut Rng, Budget) -> PropResult) {
    let mut failure: Option<(usize, usize, String)> = None;
    'outer: for case in 0..cases {
        let size = 4 + (case * 64) / cases.max(1); // grow sizes over the run
        let mut rng = Rng::new(seed_for(name, case));
        if let Err(msg) = prop(&mut rng, Budget { size }) {
            // Shrink-lite: retry the same case seed at smaller sizes and
            // report the smallest size that still fails.
            let mut min_fail = (size, msg);
            for s in (1..size).rev() {
                let mut rng = Rng::new(seed_for(name, case));
                match prop(&mut rng, Budget { size: s }) {
                    Err(m) => min_fail = (s, m),
                    Ok(()) => break,
                }
            }
            failure = Some((case, min_fail.0, min_fail.1));
            break 'outer;
        }
    }
    if let Some((case, size, msg)) = failure {
        panic!(
            "property `{name}` failed (case {case}, minimal size {size}): {msg}\n\
             reproduce with seed_for(\"{name}\", {case})"
        );
    }
}

/// Common generators.
pub mod gen {
    use super::{Budget, Rng};

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.usize_below((hi - lo).max(1))
    }

    /// Length scaled by the budget, in [0, 32*size).
    pub fn len(rng: &mut Rng, b: Budget) -> usize {
        rng.usize_below(32 * b.size.max(1))
    }

    pub fn vec_u8(rng: &mut Rng, n: usize) -> Vec<u8> {
        (0..n).map(|_| rng.below(256) as u8).collect()
    }

    /// Bytes with long runs (exercises RLE's best case).
    pub fn vec_u8_runs(rng: &mut Rng, n: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let byte = rng.below(4) as u8;
            let run = 1 + rng.usize_below(64);
            for _ in 0..run.min(n - out.len()) {
                out.push(byte);
            }
        }
        out
    }

    pub fn vec_f32(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, scale)).collect()
    }

    pub fn vec_i32(rng: &mut Rng, n: usize, max_abs: i32) -> Vec<i32> {
        (0..n)
            .map(|_| rng.below((2 * max_abs + 1) as u64) as i32 - max_abs)
            .collect()
    }

    pub fn ident(rng: &mut Rng) -> String {
        let n = 1 + rng.usize_below(12);
        (0..n)
            .map(|_| (b'a' + rng.below(26) as u8) as char)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice is identity", 50, |rng, b| {
            let n = gen::len(rng, b);
            let v = gen::vec_u8(rng, n);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            prop_assert(v == w, "reverse^2 != id")
        });
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics_with_name() {
        check("always fails", 10, |_rng, _b| Err("nope".to_string()));
    }

    #[test]
    fn deterministic_seeds() {
        let a = seed_for("x", 3);
        let b = seed_for("x", 3);
        assert_eq!(a, b);
        assert_ne!(seed_for("x", 3), seed_for("x", 4));
        assert_ne!(seed_for("x", 3), seed_for("y", 3));
    }
}
