//! Dependency-free utility substrates.
//!
//! The offline crate set has no `serde`/`serde_json`, no `rand`, no `clap`
//! and no `criterion`, so MGit carries its own minimal implementations:
//! a JSON value model + parser + writer ([`json`]), a splittable PRNG
//! ([`rng`]), a git-style argument parser ([`argparse`]), wall-clock
//! timing and bench statistics ([`timing`]), and a small property-testing
//! harness ([`proptest`]).

pub mod argparse;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod timing;

/// Worker-count heuristic behind `--jobs auto` and `mgit serve` pool
/// sizing: [`std::thread::available_parallelism`], falling back to `1`
/// (serial — the always-correct choice) when the parallelism cannot be
/// determined (restricted cgroups/sandboxes make the syscall fail).
pub fn auto_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Format a byte count human-readably (e.g. `1.50 MiB`).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} B", n)
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration in seconds human-readably (`430 ms`, `2.1 s`, `3.5 min`).
pub fn human_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2} s", s)
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(17), "17 B");
        assert_eq!(human_bytes(1536), "1.50 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(human_secs(0.0000005), "0.5 µs");
        assert_eq!(human_secs(0.043), "43.0 ms");
        assert_eq!(human_secs(2.5), "2.50 s");
        assert_eq!(human_secs(300.0), "5.0 min");
    }
}
