//! Deterministic, splittable PRNG (SplitMix64 seeding + xoshiro256**).
//!
//! Used by: model parameter initialization (mirroring the manifest's init
//! kinds), synthetic dataset generation, FL worker sampling, perturbation
//! families and the property-test harness. No `rand` crate offline, and we
//! want cross-run determinism for reproducible experiments anyway.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal sample from the Box–Muller pair.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (e.g. per worker / per tensor).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). Uses rejection to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    pub fn bool_with(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.next_f64().max(1e-300), self.next_f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        (self.normal() as f32) * std + mean
    }

    /// Sample `k` distinct indices out of `n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.usize_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut root = Rng::new(1);
        let mut x = root.split(0);
        let mut y = root.split(1);
        let xs: Vec<u64> = (0..8).map(|_| x.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| y.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(42);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m += z;
            v += z * z;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        let s = r.sample_indices(40, 5);
        assert_eq!(s.len(), 5);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 5);
        assert!(t.iter().all(|&i| i < 40));
    }
}
