//! Wall-clock timing, counters and bench statistics.
//!
//! `criterion` is unavailable offline, so the bench harness (rust/benches)
//! is built on [`BenchStats`]: warmup + N timed iterations, reporting
//! mean / median / p95 / stddev, matching the methodology we describe in
//! EXPERIMENTS.md.

use std::time::Instant;

/// A simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// Statistics over repeated timed runs of an operation.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub samples: Vec<f64>,
}

impl BenchStats {
    /// Run `f` for `warmup` untimed + `iters` timed iterations.
    pub fn measure(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchStats {
        for _ in 0..warmup {
            f();
        }
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Timer::start();
            f();
            samples.push(t.elapsed_secs());
        }
        BenchStats { name: name.to_string(), samples }
    }

    pub fn from_samples(name: &str, samples: Vec<f64>) -> BenchStats {
        BenchStats { name: name.to_string(), samples }
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|s| (s - m) * (s - m)).sum::<f64>()
            / (self.samples.len() - 1) as f64)
            .sqrt()
    }

    fn sorted(&self) -> Vec<f64> {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn percentile(&self, p: f64) -> f64 {
        let s = self.sorted();
        if s.is_empty() {
            return 0.0;
        }
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    /// One-line report: `name  mean ± σ  (median, p95, n)`.
    pub fn report(&self) -> String {
        format!(
            "{:<42} {:>10} ± {:>9}  (median {:>10}, p95 {:>10}, n={})",
            self.name,
            super::human_secs(self.mean()),
            super::human_secs(self.stddev()),
            super::human_secs(self.median()),
            super::human_secs(self.percentile(95.0)),
            self.samples.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let s = BenchStats::from_samples("t", vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.median() - 3.0).abs() < 1e-12);
        assert!((s.stddev() - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.percentile(0.0), 1.0);
    }

    #[test]
    fn measure_runs_closure() {
        let mut count = 0;
        let s = BenchStats::measure("c", 2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.samples.len(), 5);
        assert!(s.mean() >= 0.0);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed_secs();
        let b = t.elapsed_secs();
        assert!(b >= a);
    }
}
