//! Minimal JSON value model, parser and writer.
//!
//! MGit serializes all lineage-graph metadata to disk at the end of every
//! operation (§3.1 of the paper) and reads the AOT `manifest.json`; the
//! offline crate set has no `serde_json`, so this module implements the
//! subset of JSON we need: UTF-8 text, `f64` numbers (all our integers are
//! < 2^53), objects with preserved key order (stable on-disk diffs).

use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Object keys keep insertion order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ------------------------------------------------------------------
    // Accessors
    // ------------------------------------------------------------------
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Object field lookup that errors with the missing key's name.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow!("missing JSON field `{key}`"))
    }

    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow!("JSON field `{key}` is not a string"))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow!("JSON field `{key}` is not an unsigned int"))
    }

    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| anyhow!("JSON field `{key}` is not a number"))
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow!("JSON field `{key}` is not an array"))
    }

    // ------------------------------------------------------------------
    // Builders
    // ------------------------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Append a field to an object (panics if `self` is not an object —
    /// builder misuse is a programming error).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(o) => o.push((key.to_string(), value.into())),
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ------------------------------------------------------------------
    // Serialization
    // ------------------------------------------------------------------
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n == n.trunc() && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{}", n));
    } else {
        // JSON has no NaN/Inf; this only occurs for diagnostic payloads.
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------------------
// Parser (recursive descent)
// ---------------------------------------------------------------------------
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected `{}` at byte {} (found `{}`)",
                c as char,
                self.pos,
                self.peek().map(|b| b as char).unwrap_or('∅')
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => bail!("expected `,` or `}}` at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected `,` or `]` at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            // Surrogate pairs are not produced by our writer;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])?;
                    let ch = text.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::obj()
            .set("name", "bert-base")
            .set("nodes", 23usize)
            .set("ratio", Json::Num(7.25))
            .set("tags", vec!["a", "b"])
            .set("meta", Json::obj().set("ok", true).set("none", Json::Null));
        let text = v.to_string_pretty();
        let back = parse(&text).unwrap();
        assert_eq!(v, back);
        let back2 = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, back2);
    }

    #[test]
    fn parses_manifest_like_input() {
        let text = r#"{"archs": {"tx-tiny": {"d_model": 64, "layout":
            [{"name":"embed.tok","shape":[256,64],"offset":0}]}},
            "neg": -3.5e-2, "esc": "a\"b\\c\ndA"}"#;
        let v = parse(text).unwrap();
        assert_eq!(
            v.get("archs").unwrap().get("tx-tiny").unwrap().req_usize("d_model").unwrap(),
            64
        );
        assert_eq!(v.req_f64("neg").unwrap(), -0.035);
        assert_eq!(v.req_str("esc").unwrap(), "a\"b\\c\ndA");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("123 456").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        let keys: Vec<_> = v.as_obj().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }
}
