//! Federated-learning controller (paper §2, workload G3).
//!
//! Models the paper's setup: the training data lives in `n_silos` disjoint
//! label-skewed silos; each round samples `workers_per_round` silos, runs
//! `local_steps` of local SGD from the current global model, then
//! federated-averages the returns into the next global model. Every
//! worker model and every global round is registered in the lineage graph
//! (worker models are provenance children of the round's global model;
//! the next global model is a FedAvg child of the sampled workers), which
//! is exactly how "node and edge addition can be directly integrated into
//! larger applications" (§3.1.1).

use anyhow::Result;

use crate::checkpoint::Checkpoint;
use crate::data;
use crate::lineage::{LineageGraph, NodeIdx};
use crate::registry::{CreationSpec, Objective};
use crate::runtime::Runtime;
use crate::train::average_checkpoints;
use crate::update::CheckpointStore;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct FlConfig {
    pub arch: String,
    pub task: String,
    pub n_silos: usize,
    pub workers_per_round: usize,
    pub rounds: usize,
    pub local_steps: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for FlConfig {
    fn default() -> Self {
        FlConfig {
            arch: "tx-tiny".into(),
            task: "task1".into(),
            n_silos: 40,
            workers_per_round: 5,
            rounds: 10,
            local_steps: 4,
            lr: 0.05,
            seed: 17,
        }
    }
}

/// Label subset owned by silo `i` (2 of the 4 classes, round-robin) —
/// non-IID label skew.
pub fn silo_labels(i: usize) -> [i32; 2] {
    [(i % 4) as i32, ((i + 1) % 4) as i32]
}

/// Round-by-round record.
#[derive(Debug, Clone)]
pub struct FlRound {
    pub round: usize,
    pub sampled: Vec<usize>,
    pub global_node: NodeIdx,
    pub eval_acc: f32,
}

/// Run FL end-to-end, registering lineage as we go. Returns per-round
/// records; the final global model is the lineage node of the last record.
pub fn run_federated(
    rt: &Runtime,
    g: &mut LineageGraph,
    ckstore: &dyn CheckpointStore,
    cfg: &FlConfig,
) -> Result<Vec<FlRound>> {
    let zoo = rt.zoo();
    let spec = zoo.arch(&cfg.arch)?;
    let mut rng = Rng::new(cfg.seed);

    let mut global_ck = Checkpoint::init(spec, cfg.seed);
    let stored = ckstore.save(&global_ck, None)?;
    let mut global_node = g.add_node("fl/global@r0", &cfg.arch)?;
    g.node_mut(global_node).stored = Some(stored);

    let mut rounds = Vec::new();
    for round in 0..cfg.rounds {
        let sampled = rng.sample_indices(cfg.n_silos, cfg.workers_per_round);
        let mut worker_nodes = Vec::new();
        let mut worker_cks = Vec::new();
        for &silo in &sampled {
            // Local training on the silo's label-skewed data.
            let mut params = global_ck.flat.clone();
            let mut mom = vec![0f32; params.len()];
            for step in 0..cfg.local_steps {
                let batch = data::silo_cls_batch(
                    &cfg.task,
                    zoo.batch,
                    zoo.max_seq,
                    cfg.seed ^ silo as u64,
                    (round * cfg.local_steps + step) as u64,
                    &silo_labels(silo),
                )?;
                rt.train_step(&cfg.arch, Objective::Cls, &mut params, &mut mom, &batch, cfg.lr)?;
            }
            let ck = Checkpoint { arch: cfg.arch.clone(), flat: params };
            let stored = ckstore.save(
                &ck,
                // delta-compress worker models against the global model
                g.node(global_node)
                    .stored
                    .as_ref()
                    .map(|sm| (sm, &global_ck))
                    .map(|(s, c)| (s, c)),
            )?;
            let w = g.add_node(&format!("fl/worker{silo}@r{}", round + 1), &cfg.arch)?;
            g.node_mut(w).stored = Some(stored);
            g.add_edge(global_node, w)?;
            worker_nodes.push(w);
            worker_cks.push(ck);
        }

        // FedAvg into the next global model.
        let next_ck = average_checkpoints(&cfg.arch, &worker_cks)?;
        let stored = ckstore.save(
            &next_ck,
            g.node(global_node).stored.as_ref().map(|sm| (sm, &global_ck)),
        )?;
        let next_node = g.add_node(&format!("fl/global@r{}", round + 1), &cfg.arch)?;
        g.node_mut(next_node).stored = Some(stored);
        g.node_mut(next_node).creation = Some(CreationSpec::FedAvg);
        for &w in &worker_nodes {
            g.add_edge(w, next_node)?;
        }

        // Held-out accuracy of the new global model on the full task.
        let (_, acc) = rt.eval_many(
            &cfg.arch,
            Objective::Cls,
            &next_ck.flat,
            &cfg.task,
            cfg.seed,
            2,
        )?;
        rounds.push(FlRound { round: round + 1, sampled, global_node: next_node, eval_acc: acc });

        global_ck = next_ck;
        global_node = next_node;
    }
    Ok(rounds)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn silo_labels_cover_all_classes() {
        let mut seen = [false; 4];
        for i in 0..8 {
            for l in silo_labels(i) {
                seen[l as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
        assert_eq!(silo_labels(3), [3, 0]);
    }
}
