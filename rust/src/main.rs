//! `mgit` — the command-line front end (see `mgit help`).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = mgit::cli::run(argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
