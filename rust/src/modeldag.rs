//! DAG representation of a model's layers (the torch.fx substitute).
//!
//! MGit's `diff` (Algorithm 3) operates on "DAG representations … DAG
//! nodes are layers, an edge indicates dataflow". Architecture descriptors
//! in the AOT manifest carry exactly that graph; this module materializes
//! it, optionally annotated with per-layer *parameter content hashes*
//! (from a [`StoredModel`]) so contextual diffs can compare values.

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::checkpoint::ArchSpec;
use crate::delta::StoredModel;
use crate::store::{hash_bytes, ObjectId};
use crate::util::json::Json;

/// One layer.
#[derive(Debug, Clone)]
pub struct Layer {
    pub id: String,
    pub op: String,
    /// Shape signature / hyperparameters (e.g. `"64x128"`, `"h2x32"`).
    pub attrs: String,
    /// Names of parameter tensors owned by this layer.
    pub params: Vec<String>,
    /// Content ids of those tensors (empty when no model is attached).
    pub param_ids: Vec<ObjectId>,
}

impl Layer {
    /// Structural identity: op + attrs (+ param count).
    pub fn structural_key(&self) -> String {
        format!("{}|{}|{}", self.op, self.attrs, self.params.len())
    }

    /// Contextual identity: structural key + parameter content hashes.
    pub fn contextual_key(&self) -> String {
        let mut k = self.structural_key();
        for id in &self.param_ids {
            k.push('|');
            k.push_str(&id.short());
        }
        k
    }

    /// A compact hash of either key (bucket key for Algorithm 3).
    pub fn key_hash(&self, contextual: bool) -> u64 {
        let k = if contextual { self.contextual_key() } else { self.structural_key() };
        let h = hash_bytes(k.as_bytes());
        u64::from_le_bytes(h.0[..8].try_into().unwrap())
    }
}

/// The layer DAG, in topological order (guaranteed by construction).
#[derive(Debug, Clone)]
pub struct ModelDag {
    pub layers: Vec<Layer>,
    /// Edges as (src_index, dst_index).
    pub edges: Vec<(usize, usize)>,
    by_id: HashMap<String, usize>,
}

impl ModelDag {
    /// Build from an arch descriptor; if `stored` is given, annotate each
    /// layer with its parameters' content ids.
    pub fn from_arch(spec: &ArchSpec, stored: Option<&StoredModel>) -> Result<ModelDag> {
        let mut layers = Vec::new();
        let mut by_id = HashMap::new();
        for nj in spec.dag.req_arr("nodes")? {
            let id = nj.req_str("id")?.to_string();
            let params: Vec<String> = nj
                .req_arr("params")?
                .iter()
                .map(|p| p.as_str().unwrap_or_default().to_string())
                .collect();
            let param_ids = match stored {
                None => Vec::new(),
                Some(sm) => params
                    .iter()
                    .map(|p| {
                        sm.param_id(p)
                            .ok_or_else(|| anyhow!("stored model missing param `{p}`"))
                    })
                    .collect::<Result<Vec<_>>>()?,
            };
            by_id.insert(id.clone(), layers.len());
            layers.push(Layer {
                id,
                op: nj.req_str("op")?.to_string(),
                attrs: nj.req_str("attrs")?.to_string(),
                params,
                param_ids,
            });
        }
        let mut edges = Vec::new();
        for ej in spec.dag.req_arr("edges")? {
            let pair = ej.as_arr().ok_or_else(|| anyhow!("edge is not a pair"))?;
            let src = pair[0].as_str().and_then(|s| by_id.get(s)).copied();
            let dst = pair[1].as_str().and_then(|s| by_id.get(s)).copied();
            match (src, dst) {
                (Some(s), Some(d)) => edges.push((s, d)),
                _ => return Err(anyhow!("edge references unknown layer")),
            }
        }
        Ok(ModelDag { layers, edges, by_id })
    }

    pub fn layer_index(&self, id: &str) -> Option<usize> {
        self.by_id.get(id).copied()
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    /// Successor layer indices.
    pub fn successors(&self, i: usize) -> impl Iterator<Item = usize> + '_ {
        self.edges.iter().filter(move |(s, _)| *s == i).map(|(_, d)| *d)
    }

    /// Is there a dataflow path from `a` to `b` (a strictly before b)?
    pub fn reaches(&self, a: usize, b: usize) -> bool {
        if a == b {
            return false;
        }
        let mut seen = vec![false; self.layers.len()];
        let mut stack: Vec<usize> = self.successors(a).collect();
        while let Some(i) = stack.pop() {
            if i == b {
                return true;
            }
            if seen[i] {
                continue;
            }
            seen[i] = true;
            stack.extend(self.successors(i));
        }
        false
    }

    /// Do two layer sets have a dataflow dependency (either direction),
    /// or does some downstream layer consume both? In a connected
    /// feed-forward network the former implies the latter check is mainly
    /// for parallel branches joining later.
    pub fn sets_dependent(&self, xs: &[usize], ys: &[usize]) -> bool {
        for &x in xs {
            for &y in ys {
                if self.reaches(x, y) || self.reaches(y, x) {
                    return true;
                }
                // Common downstream consumer.
                for j in 0..self.layers.len() {
                    if self.reaches(x, j) && self.reaches(y, j) {
                        return true;
                    }
                }
            }
        }
        false
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set(
                "nodes",
                Json::Arr(
                    self.layers
                        .iter()
                        .map(|l| {
                            Json::obj()
                                .set("id", l.id.as_str())
                                .set("op", l.op.as_str())
                                .set("attrs", l.attrs.as_str())
                                .set(
                                    "params",
                                    l.params.iter().map(|p| p.as_str()).collect::<Vec<_>>(),
                                )
                        })
                        .collect(),
                ),
            )
            .set(
                "edges",
                Json::Arr(
                    self.edges
                        .iter()
                        .map(|(s, d)| {
                            Json::Arr(vec![
                                Json::from(self.layers[*s].id.as_str()),
                                Json::from(self.layers[*d].id.as_str()),
                            ])
                        })
                        .collect(),
                ),
            )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::tiny_zoo;
    use crate::checkpoint::Checkpoint;
    use crate::delta::store_raw;
    use crate::store::Store;

    #[test]
    fn builds_from_arch() {
        let zoo = tiny_zoo();
        let spec = zoo.arch("t0").unwrap();
        let dag = ModelDag::from_arch(spec, None).unwrap();
        assert_eq!(dag.n_layers(), 2);
        assert_eq!(dag.n_edges(), 1);
        assert_eq!(dag.layers[0].op, "linear");
        assert!(dag.layers[0].param_ids.is_empty());
    }

    #[test]
    fn annotates_param_hashes() {
        let zoo = tiny_zoo();
        let spec = zoo.arch("t0").unwrap();
        let store = Store::in_memory();
        let ck = Checkpoint::init(spec, 1);
        let (sm, _) = store_raw(&store, spec, &ck).unwrap();
        let dag = ModelDag::from_arch(spec, Some(&sm)).unwrap();
        assert_eq!(dag.layers[0].param_ids.len(), 1);
        assert_eq!(dag.layers[1].param_ids.len(), 2);
        // Same params -> same contextual key; different seed -> different.
        let dag2 = ModelDag::from_arch(
            spec,
            Some(&store_raw(&store, spec, &Checkpoint::init(spec, 1)).unwrap().0),
        )
        .unwrap();
        assert_eq!(dag.layers[0].contextual_key(), dag2.layers[0].contextual_key());
        let dag3 = ModelDag::from_arch(
            spec,
            Some(&store_raw(&store, spec, &Checkpoint::init(spec, 9)).unwrap().0),
        )
        .unwrap();
        assert_ne!(dag.layers[0].contextual_key(), dag3.layers[0].contextual_key());
        // structural keys agree regardless of values
        assert_eq!(dag.layers[0].structural_key(), dag3.layers[0].structural_key());
    }

    #[test]
    fn reachability() {
        let zoo = tiny_zoo();
        let spec = zoo.arch("t0").unwrap();
        let dag = ModelDag::from_arch(spec, None).unwrap();
        assert!(dag.reaches(0, 1));
        assert!(!dag.reaches(1, 0));
        assert!(!dag.reaches(0, 0));
        assert!(dag.sets_dependent(&[0], &[1]));
    }
}
