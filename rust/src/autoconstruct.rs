//! Automated lineage-graph construction (paper §3.2).
//!
//! Given a pool of models created *outside* MGit (e.g. downloaded
//! checkpoints), insert each into the lineage graph by pairwise diffing
//! against all present models: "MGit locates the model in the graph that
//! has the smallest contextual and then structural divergence score; this
//! node is chosen as the parent. If no model is sufficiently contextually
//! or structurally similar, x is added as a root."
//!
//! Exact-hash contextual divergence alone cannot rank fully-finetuned
//! children (they share no tensor hashes with their parent), so the
//! contextual signal is refined with the normalized parameter-value
//! distance of [`crate::diff::value_distance`] — this is the "comparing
//! attributes or also parameter values" in the paper's description of
//! contextual diffs, and is what lets frozen-weight *and* fully-finetuned
//! children find their parents (22/23 on G1).

use anyhow::Result;

use crate::checkpoint::{ArchSpec, Checkpoint};
use crate::diff::{divergence_scores, value_distance};
use crate::modeldag::ModelDag;

/// A candidate model for insertion / comparison.
pub struct PoolModel<'a> {
    pub name: String,
    pub spec: &'a ArchSpec,
    pub dag: ModelDag,
    pub ck: Checkpoint,
}

/// Divergence triple for one candidate parent.
#[derive(Debug, Clone, Copy)]
pub struct Scores {
    pub structural: f64,
    pub contextual: f64,
    pub value: f64,
}

impl Scores {
    /// Lexicographic-ish ranking key: hash-contextual first (exact shared
    /// tensors dominate), then value distance, then structure.
    fn key(&self) -> (u64, u64, u64) {
        let q = |x: f64| (x * 1e9) as u64;
        (q(self.contextual), q(self.value), q(self.structural))
    }
}

/// Insertion thresholds (defaults tuned on the G1-style zoo).
#[derive(Debug, Clone, Copy)]
pub struct AutoConfig {
    /// Accept a parent when contextual (hash) divergence is below this…
    pub ctx_threshold: f64,
    /// …or when value distance is below this (finetuned children).
    pub value_threshold: f64,
    /// Structural divergence above this disqualifies a candidate outright
    /// (completely different architectures).
    pub max_structural: f64,
}

impl Default for AutoConfig {
    fn default() -> Self {
        AutoConfig { ctx_threshold: 0.999, value_threshold: 0.45, max_structural: 0.5 }
    }
}

/// Score `child` against one candidate `parent`.
pub fn score_pair(parent: &PoolModel<'_>, child: &PoolModel<'_>) -> Result<Scores> {
    let (structural, contextual) = divergence_scores(&parent.dag, &child.dag);
    let value = if structural <= 0.9999 {
        value_distance(
            &parent.dag, parent.spec, &parent.ck, &child.dag, child.spec, &child.ck,
        )?
    } else {
        1.0
    };
    Ok(Scores { structural, contextual, value })
}

/// Choose the best parent for `child` among `pool`, or `None` → root.
/// Returns (pool index, scores).
pub fn choose_parent(
    pool: &[PoolModel<'_>],
    child: &PoolModel<'_>,
    cfg: &AutoConfig,
) -> Result<Option<(usize, Scores)>> {
    let mut best: Option<(usize, Scores)> = None;
    for (i, cand) in pool.iter().enumerate() {
        let s = score_pair(cand, child)?;
        if s.structural > cfg.max_structural {
            continue;
        }
        let sufficiently_similar = s.contextual < cfg.ctx_threshold
            || s.value < cfg.value_threshold;
        if !sufficiently_similar {
            continue;
        }
        match &best {
            None => best = Some((i, s)),
            Some((_, bs)) if s.key() < bs.key() => best = Some((i, s)),
            _ => {}
        }
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::testutil::normal_zoo;
    use crate::delta::store_raw;
    use crate::store::Store;

    fn pool_model<'a>(
        zoo: &'a crate::checkpoint::ModelZoo,
        store: &Store,
        name: &str,
        arch: &str,
        ck: Checkpoint,
    ) -> PoolModel<'a> {
        let spec = zoo.arch(arch).unwrap();
        let (sm, _) = store_raw(store, spec, &ck).unwrap();
        PoolModel {
            name: name.to_string(),
            spec,
            dag: ModelDag::from_arch(spec, Some(&sm)).unwrap(),
            ck,
        }
    }

    #[test]
    fn finetuned_child_finds_parent() {
        let zoo = normal_zoo();
        let store = Store::in_memory();
        let spec = zoo.arch("n0").unwrap();
        let root_ck = Checkpoint::init(spec, 1);
        let mut child_ck = root_ck.clone();
        for x in child_ck.flat.iter_mut() {
            *x += 0.002;
        }
        let unrelated_ck = Checkpoint::init(spec, 77);

        let pool = vec![
            pool_model(&zoo, &store, "root", "n0", root_ck),
            pool_model(&zoo, &store, "unrelated", "n0", unrelated_ck),
        ];
        let child = pool_model(&zoo, &store, "child", "n0", child_ck);
        let got = choose_parent(&pool, &child, &AutoConfig::default()).unwrap();
        let (idx, scores) = got.expect("should find a parent");
        assert_eq!(pool[idx].name, "root");
        assert!(scores.value < 0.1);
    }

    #[test]
    fn frozen_weight_child_prefers_exact_sharer() {
        let zoo = normal_zoo();
        let store = Store::in_memory();
        let spec = zoo.arch("n0").unwrap();
        let root_ck = Checkpoint::init(spec, 1);
        // Child shares w.a exactly (frozen), head differs.
        let mut child_ck = root_ck.clone();
        for x in child_ck.param_mut(spec, "w.head").unwrap().iter_mut() { *x = 3.0; }
        // Decoy: close in values overall but shares no exact tensor.
        let mut decoy_ck = root_ck.clone();
        for x in decoy_ck.flat.iter_mut() {
            *x += 1e-3;
        }
        let pool = vec![
            pool_model(&zoo, &store, "root", "n0", root_ck),
            pool_model(&zoo, &store, "decoy", "n0", decoy_ck),
        ];
        let child = pool_model(&zoo, &store, "child", "n0", child_ck);
        let (idx, scores) =
            choose_parent(&pool, &child, &AutoConfig::default()).unwrap().unwrap();
        assert_eq!(pool[idx].name, "root");
        assert!(scores.contextual < 1.0, "shared frozen tensor not seen");
    }

    #[test]
    fn dissimilar_model_becomes_root() {
        let zoo = normal_zoo();
        let store = Store::in_memory();
        let pool = vec![pool_model(
            &zoo,
            &store,
            "a",
            "n0",
            Checkpoint::init(zoo.arch("n0").unwrap(), 1),
        )];
        let child = pool_model(
            &zoo,
            &store,
            "b",
            "n0",
            Checkpoint::init(zoo.arch("n0").unwrap(), 999),
        );
        let got = choose_parent(&pool, &child, &AutoConfig::default()).unwrap();
        assert!(got.is_none(), "independently-initialized model must be a root");
    }
}
