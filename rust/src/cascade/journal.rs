//! Journaling — crash-safe progress records for resumable cascades.
//!
//! A journaled cascade persists two artifacts under a directory (the CLI
//! uses `.mgit/cascade-journal/`):
//!
//! * `plan.json` — the full [`CascadePlan`], written once before
//!   execution starts (node references by *name*, so the plan re-binds
//!   against the saved graph on resume);
//! * `done.jsonl` — one appended, fsync'd line per completed task with
//!   every member's [`StoredModel`]. The referenced CAS objects are
//!   already durable when the line is written (`CheckpointStore::save`
//!   writes through to the object store), so a replayed record is a
//!   fully materialized model.
//!
//! After a crash or failure, [`load_journal`] returns the plan plus the
//! completed-task map; the scheduler then executes exactly the
//! unfinished suffix. A torn trailing line (crash mid-append) is
//! ignored, which at worst re-trains the one task whose record was cut
//! short — content addressing makes the re-store idempotent.

use std::collections::HashMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::delta::StoredModel;
use crate::lineage::{LineageGraph, NodeIdx};
use crate::util::json::{self, Json};

use super::plan::CascadePlan;
use super::schedule::DoneTasks;

/// Append-only journal handle shared by the scheduler's workers.
pub struct CascadeJournal {
    dir: PathBuf,
    file: std::sync::Mutex<fs::File>,
}

impl CascadeJournal {
    /// Start a fresh journal: write `plan.json` and open `done.jsonl`.
    /// Fails if `dir` already holds a journal (an unfinished cascade must
    /// be resumed or explicitly abandoned first).
    pub fn create(dir: &Path, plan: &CascadePlan, g: &LineageGraph) -> Result<CascadeJournal> {
        if dir.join("plan.json").exists() {
            bail!(
                "a cascade journal already exists at {} (resume it or delete the directory)",
                dir.display()
            );
        }
        fs::create_dir_all(dir)
            .with_context(|| format!("creating journal dir {}", dir.display()))?;
        // Atomic plan write (temp + fsync + rename): a crash mid-create
        // must not leave a plan.json that parses as garbage — the
        // journal's very existence gates `mgit cascade`.
        let tmp = dir.join("plan.json.tmp");
        {
            let mut f = fs::File::create(&tmp)?;
            f.write_all(plan.to_json(g).to_string_pretty().as_bytes())?;
            f.sync_data()?;
        }
        fs::rename(&tmp, dir.join("plan.json"))?;
        let file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(dir.join("done.jsonl"))?;
        Ok(CascadeJournal { dir: dir.to_path_buf(), file: std::sync::Mutex::new(file) })
    }

    /// Re-open an existing journal for appending (the resume path).
    pub fn reopen(dir: &Path) -> Result<CascadeJournal> {
        if !dir.join("plan.json").exists() {
            bail!("no cascade journal at {}", dir.display());
        }
        let file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(dir.join("done.jsonl"))?;
        Ok(CascadeJournal { dir: dir.to_path_buf(), file: std::sync::Mutex::new(file) })
    }

    /// Where this journal lives.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Append one completed task's records and flush them to disk. Safe
    /// to call from multiple worker threads (writes are serialized).
    pub fn record(
        &self,
        g: &LineageGraph,
        task: usize,
        results: &[(NodeIdx, StoredModel)],
    ) -> Result<()> {
        let arr: Vec<Json> = results
            .iter()
            .map(|(idx, sm)| {
                Json::obj()
                    .set("node", g.node(*idx).name.as_str())
                    .set("stored", sm.to_json())
            })
            .collect();
        let line = Json::obj()
            .set("task", task)
            .set("results", Json::Arr(arr))
            .to_string_compact();
        let mut f = self.file.lock().unwrap();
        writeln!(f, "{line}")?;
        f.sync_data()?;
        Ok(())
    }
}

/// The journal directory used by the CLI for a repository rooted at the
/// given `.mgit` directory.
pub fn journal_dir(mgit_dir: &Path) -> PathBuf {
    mgit_dir.join("cascade-journal")
}

/// Load a journal: the persisted plan (re-bound against `g`) plus every
/// *complete* done record. Incomplete or torn records are dropped — the
/// scheduler simply re-runs those tasks.
pub fn load_journal(dir: &Path, g: &LineageGraph) -> Result<(CascadePlan, DoneTasks)> {
    let plan_text = fs::read_to_string(dir.join("plan.json"))
        .with_context(|| format!("no cascade journal at {}", dir.display()))?;
    let plan = CascadePlan::from_json(&json::parse(&plan_text)?, g)
        .context("journaled plan does not match the saved graph")?;
    let mut done: DoneTasks = HashMap::new();
    let text = fs::read_to_string(dir.join("done.jsonl")).unwrap_or_default();
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let Ok(j) = json::parse(line) else {
            // Torn tail from a crash mid-append: everything before it is
            // intact (records are written and fsync'd in completion
            // order), so stop replaying here.
            break;
        };
        let tid = j.req_usize("task")?;
        if tid >= plan.tasks.len() {
            bail!("journal references unknown task {tid}");
        }
        let mut outs = Vec::new();
        for r in j.req_arr("results")? {
            let name = r.req_str("node")?;
            let idx = g
                .idx(name)
                .map_err(|_| anyhow!("journaled node `{name}` missing from the graph"))?;
            outs.push((idx, StoredModel::from_json(r.req("stored")?)?));
        }
        if outs.len() != plan.tasks[tid].members.len() {
            continue; // partial record: re-run the task
        }
        done.insert(tid, outs);
    }
    Ok((plan, done))
}

/// Whether `dir` holds a journal (an interrupted cascade).
pub fn journal_exists(dir: &Path) -> bool {
    dir.join("plan.json").exists()
}

/// Delete a finished journal. Missing directories are fine.
pub fn remove_journal(dir: &Path) -> Result<()> {
    if dir.exists() {
        fs::remove_dir_all(dir)
            .with_context(|| format!("removing cascade journal {}", dir.display()))?;
    }
    Ok(())
}
