//! Planning — Phase A of Algorithm 2 as a pure data structure.
//!
//! [`plan_cascade`] performs every *graph mutation* of an update cascade
//! up front (create the next-version nodes, wire version + provenance
//! edges, copy creation functions) and returns an immutable
//! [`CascadePlan`] describing the *execution* that remains: one
//! [`PlanTask`] per creation to run, with parent sets, MTL group
//! membership and inter-task dependencies recorded as plain data. The
//! scheduler ([`crate::cascade::schedule`]) then executes the plan
//! without ever touching the graph, which is what makes wavefront
//! parallelism and crash-resume ([`crate::cascade::journal`]) possible.
//!
//! Determinism: provenance edges are wired in *sorted node order* (not
//! `HashMap` iteration order as the old serial implementation did), so
//! two cascades over identical graphs produce byte-identical graph JSON
//! and identical plans.
//!
//! Planning mutates the graph, so callers reach it through
//! [`crate::lineage::GraphStore`]'s `DerefMut` — on a mapped binary
//! repo that materializes the full image first (a cascade rewrites
//! much of the graph anyway); the subsequent `Repo::save` re-encodes
//! `graph.bin` compactly.

use std::collections::{HashMap, HashSet};

use anyhow::{anyhow, bail, Result};

use crate::lineage::{traversal, LineageGraph, NodeIdx};
use crate::registry::CreationSpec;
use crate::update::next_version_name;
use crate::util::json::Json;

/// One model to (re-)create: the new node, its previous version (the
/// delta-compression parent), and everything the executor needs.
#[derive(Debug, Clone)]
pub struct PlanMember {
    /// The existing node this is the next version of.
    pub old: NodeIdx,
    /// The freshly created (empty) next-version node.
    pub new: NodeIdx,
    /// Name of `new` (journal key; indices are not stable across repos).
    pub name: String,
    /// Architecture (model_type) handed to the executor.
    pub arch: String,
    /// The creation function to re-execute.
    pub spec: CreationSpec,
    /// Effective provenance parents of `new` (next versions where the
    /// parent is inside the cascade, current versions otherwise).
    pub parents: Vec<NodeIdx>,
}

/// A schedulable unit: a single creation, or a whole MTL group executed
/// once as a barrier task (the merged `cr'` of paper §5).
#[derive(Debug, Clone)]
pub struct PlanTask {
    /// Group members (one for non-MTL tasks), sorted by name for MTL
    /// groups so the executor's spec order is deterministic.
    pub members: Vec<PlanMember>,
    /// Whether this task runs through `execute_mtl_group`.
    pub mtl: bool,
    /// Index into `members` whose parent set feeds the executor (the
    /// lowest-index member — the one the serial implementation reached
    /// first in topological order).
    pub parent_source: usize,
    /// Task ids that must complete before this one can run.
    pub deps: Vec<usize>,
    /// Task ids unblocked by this one (inverse of `deps`).
    pub dependents: Vec<usize>,
}

/// Immutable output of Phase A: what to execute, in what partial order.
#[derive(Debug, Clone)]
pub struct CascadePlan {
    /// The updated model's old version.
    pub m: NodeIdx,
    /// The user-registered new version of `m`.
    pub m_new: NodeIdx,
    /// Execution units in deterministic creation order.
    pub tasks: Vec<PlanTask>,
    /// Descendants skipped because they had no creation function.
    pub skipped_no_cr: Vec<NodeIdx>,
    /// new-node index -> owning task id.
    pub task_of: HashMap<NodeIdx, usize>,
}

impl CascadePlan {
    /// Total number of models the plan will create.
    pub fn n_models(&self) -> usize {
        self.tasks.iter().map(|t| t.members.len()).sum()
    }

    /// Serialize for the on-disk journal. Nodes are stored by *name*
    /// (indices are re-resolved against the saved graph on resume).
    pub fn to_json(&self, g: &LineageGraph) -> Json {
        let tasks: Vec<Json> = self
            .tasks
            .iter()
            .map(|t| {
                let members: Vec<Json> = t
                    .members
                    .iter()
                    .map(|mb| {
                        Json::obj()
                            .set("old", g.node(mb.old).name.as_str())
                            .set("new", mb.name.as_str())
                            .set("arch", mb.arch.as_str())
                            .set(
                                "parents",
                                Json::Arr(
                                    mb.parents
                                        .iter()
                                        .map(|&p| Json::from(g.node(p).name.as_str()))
                                        .collect(),
                                ),
                            )
                            .set("spec", mb.spec.to_json())
                    })
                    .collect();
                Json::obj()
                    .set("mtl", t.mtl)
                    .set("parent_source", t.parent_source)
                    .set("deps", Json::Arr(t.deps.iter().map(|&d| Json::from(d)).collect()))
                    .set("members", Json::Arr(members))
            })
            .collect();
        Json::obj()
            .set("version", 1usize)
            .set("m", g.node(self.m).name.as_str())
            .set("m_new", g.node(self.m_new).name.as_str())
            .set(
                "skipped_no_cr",
                Json::Arr(
                    self.skipped_no_cr
                        .iter()
                        .map(|&i| Json::from(g.node(i).name.as_str()))
                        .collect(),
                ),
            )
            .set("tasks", Json::Arr(tasks))
    }

    /// Rebuild a plan from [`CascadePlan::to_json`] against a graph that
    /// already contains the Phase-A nodes (the repo graph is saved right
    /// after planning, before execution starts).
    pub fn from_json(j: &Json, g: &LineageGraph) -> Result<CascadePlan> {
        let m = g.idx(j.req_str("m")?)?;
        let m_new = g.idx(j.req_str("m_new")?)?;
        let mut skipped_no_cr = Vec::new();
        for s in j.req_arr("skipped_no_cr")? {
            let name = s.as_str().ok_or_else(|| anyhow!("bad skipped entry"))?;
            skipped_no_cr.push(g.idx(name)?);
        }
        let mut tasks = Vec::new();
        let mut task_of = HashMap::new();
        for (tid, tj) in j.req_arr("tasks")?.iter().enumerate() {
            let mtl = tj.req("mtl")?.as_bool().unwrap_or(false);
            let parent_source = tj.req_usize("parent_source")?;
            let mut deps = Vec::new();
            for d in tj.req_arr("deps")? {
                deps.push(d.as_usize().ok_or_else(|| anyhow!("bad dep index"))?);
            }
            let mut members = Vec::new();
            for mj in tj.req_arr("members")? {
                let name = mj.req_str("new")?.to_string();
                let new = g.idx(&name)?;
                let mut parents = Vec::new();
                for p in mj.req_arr("parents")? {
                    let pname = p.as_str().ok_or_else(|| anyhow!("bad parent entry"))?;
                    parents.push(g.idx(pname)?);
                }
                members.push(PlanMember {
                    old: g.idx(mj.req_str("old")?)?,
                    new,
                    name,
                    arch: mj.req_str("arch")?.to_string(),
                    spec: CreationSpec::from_json(mj.req("spec")?)?,
                    parents,
                });
            }
            if parent_source >= members.len() {
                bail!("task {tid}: parent_source out of range");
            }
            for mb in &members {
                task_of.insert(mb.new, tid);
            }
            tasks.push(PlanTask { members, mtl, parent_source, deps, dependents: Vec::new() });
        }
        for tid in 0..tasks.len() {
            for d in tasks[tid].deps.clone() {
                if d >= tasks.len() {
                    bail!("task {tid}: dependency {d} out of range");
                }
                tasks[d].dependents.push(tid);
            }
        }
        let plan = CascadePlan { m, m_new, tasks, skipped_no_cr, task_of };
        plan.check_acyclic()?;
        Ok(plan)
    }

    /// Kahn's algorithm over the task graph; MTL grouping can in theory
    /// fold a provenance path back into its own group, which would stall
    /// the scheduler forever — fail fast instead.
    fn check_acyclic(&self) -> Result<()> {
        let mut indeg: Vec<usize> = self.tasks.iter().map(|t| t.deps.len()).collect();
        let mut queue: Vec<usize> =
            (0..self.tasks.len()).filter(|&t| indeg[t] == 0).collect();
        let mut seen = 0;
        while let Some(t) = queue.pop() {
            seen += 1;
            for &d in &self.tasks[t].dependents {
                indeg[d] -= 1;
                if indeg[d] == 0 {
                    queue.push(d);
                }
            }
        }
        if seen != self.tasks.len() {
            bail!(
                "cascade plan has a dependency cycle ({} of {} tasks unreachable; \
                 an MTL group probably spans a provenance chain)",
                self.tasks.len() - seen,
                self.tasks.len()
            );
        }
        Ok(())
    }
}

/// Phase A of Algorithm 2. Creates an (empty) next version of every
/// provenance descendant of `m` that has a creation function, wires
/// version + provenance edges, and returns the execution plan. `m_new`
/// must already be registered as the next version of `m` (the CLI's
/// `cascade` command does that setup).
pub fn plan_cascade(
    g: &mut LineageGraph,
    m: NodeIdx,
    m_new: NodeIdx,
    skip: impl Fn(&LineageGraph, NodeIdx) -> bool,
    terminate: impl Fn(&LineageGraph, NodeIdx) -> bool,
) -> Result<CascadePlan> {
    if g.next_version(m) != Some(m_new) {
        bail!("m' must be the registered next version of m");
    }

    // BFS over m's provenance descendants, honouring skip/terminate.
    let descendants = traversal::bfs(
        g,
        m,
        traversal::EdgeFilter::Provenance,
        |g2, i| i == m || skip(g2, i),
        &terminate,
    );

    // Create the next-version nodes in BFS order (matches the serial
    // implementation, so node indices — and graph JSON — are identical).
    let mut skipped_no_cr = Vec::new();
    let mut next_of: HashMap<NodeIdx, NodeIdx> = HashMap::from([(m, m_new)]);
    let mut created: Vec<(NodeIdx, NodeIdx)> = Vec::new(); // (old, new)
    for &x in &descendants {
        if g.node(x).creation.is_none() {
            skipped_no_cr.push(x);
            continue;
        }
        let name = next_version_name(g, &g.node(x).name);
        let model_type = g.node(x).model_type.clone();
        let x_new = g.add_node(&name, &model_type)?;
        g.node_mut(x_new).creation = g.node(x).creation.clone();
        g.node_mut(x_new).metadata = g.node(x).metadata.clone();
        g.add_version_edge(x, x_new)?;
        next_of.insert(x, x_new);
        created.push((x, x_new));
    }

    // Provenance edges: from the next version of each parent where one
    // exists, falling back to the current parent. Iterate in sorted node
    // order — per-child parent order is fixed either way, but sorted
    // iteration also pins the children order on shared parents, making
    // the whole Phase-A mutation reproducible run to run.
    let mut wiring = created.clone();
    wiring.sort_unstable_by_key(|&(x, _)| x);
    for &(x, x_new) in &wiring {
        let parents = g.node(x).prov_parents.clone();
        for p in parents {
            let p_eff = next_of.get(&p).copied().unwrap_or(p);
            g.add_edge(p_eff, x_new)?;
        }
    }

    // Fold the created nodes into tasks: MTL members sharing a group are
    // gathered into one barrier task; everything else is a task of one.
    let mut tasks: Vec<PlanTask> = Vec::new();
    let mut task_of: HashMap<NodeIdx, usize> = HashMap::new();
    for &(x, x_new) in &created {
        if task_of.contains_key(&x_new) {
            continue; // already claimed by an earlier MTL group
        }
        let spec = g.node(x_new).creation.clone().expect("created nodes carry a creation fn");
        let tid = tasks.len();
        let mut member_nodes: Vec<(NodeIdx, NodeIdx)> = vec![(x, x_new)];
        let mtl = matches!(&spec, CreationSpec::Mtl { .. });
        if let CreationSpec::Mtl { group, .. } = &spec {
            let group_tasks: HashSet<&String> = group.iter().collect();
            for &(y, y_new) in &created {
                if y_new == x_new || task_of.contains_key(&y_new) {
                    continue;
                }
                if let Some(CreationSpec::Mtl { task, .. }) = &g.node(y_new).creation {
                    if group_tasks.contains(task) {
                        member_nodes.push((y, y_new));
                    }
                }
            }
            member_nodes.sort_by(|&(_, a), &(_, b)| g.node(a).name.cmp(&g.node(b).name));
        }
        let parent_source = member_nodes
            .iter()
            .enumerate()
            .min_by_key(|&(_, &(_, n))| n)
            .map(|(i, _)| i)
            .unwrap_or(0);
        let members: Vec<PlanMember> = member_nodes
            .iter()
            .map(|&(old, new)| PlanMember {
                old,
                new,
                name: g.node(new).name.clone(),
                arch: g.node(new).model_type.clone(),
                spec: g.node(new).creation.clone().expect("created nodes carry a creation fn"),
                parents: g.node(new).prov_parents.clone(),
            })
            .collect();
        for mb in &members {
            task_of.insert(mb.new, tid);
        }
        tasks.push(PlanTask {
            members,
            mtl,
            parent_source,
            deps: Vec::new(),
            dependents: Vec::new(),
        });
    }

    // Dependencies: task A waits on task B when any member of A has a
    // provenance parent created by B.
    for tid in 0..tasks.len() {
        let mut deps: Vec<usize> = tasks[tid]
            .members
            .iter()
            .flat_map(|mb| mb.parents.iter())
            .filter_map(|p| task_of.get(p).copied())
            .filter(|&d| d != tid)
            .collect();
        deps.sort_unstable();
        deps.dedup();
        tasks[tid].deps = deps;
    }
    for tid in 0..tasks.len() {
        for d in tasks[tid].deps.clone() {
            tasks[d].dependents.push(tid);
        }
    }

    let plan = CascadePlan { m, m_new, tasks, skipped_no_cr, task_of };
    plan.check_acyclic()?;
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::{FreezeSpec, Objective};

    fn finetune(task: &str) -> CreationSpec {
        CreationSpec::Finetune {
            task: task.into(),
            objective: Objective::Cls,
            steps: 1,
            lr: 0.1,
            seed: 0,
            freeze: FreezeSpec::None,
            perturb: None,
        }
    }

    /// m -> a -> b ; m -> c(no cr); m2 registered as m's next version.
    fn chain_graph() -> (LineageGraph, NodeIdx, NodeIdx) {
        let mut g = LineageGraph::new();
        let m = g.add_node("m", "t").unwrap();
        let a = g.add_node("a", "t").unwrap();
        let b = g.add_node("b", "t").unwrap();
        let c = g.add_node("c", "t").unwrap();
        g.add_edge(m, a).unwrap();
        g.add_edge(a, b).unwrap();
        g.add_edge(m, c).unwrap();
        g.register_creation_function(a, finetune("t1")).unwrap();
        g.register_creation_function(b, finetune("t2")).unwrap();
        let m2 = g.add_node("m@v2", "t").unwrap();
        g.add_version_edge(m, m2).unwrap();
        (g, m, m2)
    }

    #[test]
    fn plan_builds_chain_dependencies() {
        let (mut g, m, m2) = chain_graph();
        let plan = plan_cascade(&mut g, m, m2, |_, _| false, |_, _| false).unwrap();
        assert_eq!(plan.tasks.len(), 2);
        assert_eq!(plan.skipped_no_cr.len(), 1);
        // a@v2 has no created parents; b@v2 depends on a@v2's task.
        let a2 = g.idx("a@v2").unwrap();
        let b2 = g.idx("b@v2").unwrap();
        let ta = plan.task_of[&a2];
        let tb = plan.task_of[&b2];
        assert!(plan.tasks[ta].deps.is_empty());
        assert_eq!(plan.tasks[tb].deps, vec![ta]);
        assert_eq!(plan.tasks[ta].dependents, vec![tb]);
        // Parent wiring: a@v2 <- m@v2, b@v2 <- a@v2.
        assert_eq!(g.node(a2).prov_parents, vec![m2]);
        assert_eq!(g.node(b2).prov_parents, vec![a2]);
        g.integrity_check().unwrap();
    }

    #[test]
    fn plan_requires_version_edge() {
        let (mut g, m, _) = chain_graph();
        let a = g.idx("a").unwrap();
        assert!(plan_cascade(&mut g, m, a, |_, _| false, |_, _| false).is_err());
    }

    #[test]
    fn mtl_members_fold_into_one_task() {
        let mut g = LineageGraph::new();
        let m = g.add_node("m", "t").unwrap();
        let t1 = g.add_node("t1", "t").unwrap();
        let t2 = g.add_node("t2", "t").unwrap();
        g.add_edge(m, t1).unwrap();
        g.add_edge(m, t2).unwrap();
        let mtl = |task: &str| CreationSpec::Mtl {
            task: task.into(),
            group: vec!["t1".into(), "t2".into()],
            steps: 1,
            lr: 0.1,
            seed: 0,
        };
        g.register_creation_function(t1, mtl("t1")).unwrap();
        g.register_creation_function(t2, mtl("t2")).unwrap();
        let m2 = g.add_node("m@v2", "t").unwrap();
        g.add_version_edge(m, m2).unwrap();
        let plan = plan_cascade(&mut g, m, m2, |_, _| false, |_, _| false).unwrap();
        assert_eq!(plan.tasks.len(), 1);
        assert!(plan.tasks[0].mtl);
        assert_eq!(plan.tasks[0].members.len(), 2);
        // Members sorted by name.
        assert_eq!(plan.tasks[0].members[0].name, "t1@v2");
        assert_eq!(plan.tasks[0].members[1].name, "t2@v2");
    }

    #[test]
    fn plan_json_roundtrip() {
        let (mut g, m, m2) = chain_graph();
        let plan = plan_cascade(&mut g, m, m2, |_, _| false, |_, _| false).unwrap();
        let j = plan.to_json(&g);
        let back = CascadePlan::from_json(&j, &g).unwrap();
        assert_eq!(back.tasks.len(), plan.tasks.len());
        assert_eq!(back.m, plan.m);
        assert_eq!(back.m_new, plan.m_new);
        assert_eq!(back.skipped_no_cr, plan.skipped_no_cr);
        for (a, b) in back.tasks.iter().zip(&plan.tasks) {
            assert_eq!(a.deps, b.deps);
            assert_eq!(a.dependents, b.dependents);
            assert_eq!(a.mtl, b.mtl);
            assert_eq!(a.parent_source, b.parent_source);
            assert_eq!(a.members.len(), b.members.len());
            for (x, y) in a.members.iter().zip(&b.members) {
                assert_eq!((x.old, x.new, &x.name), (y.old, y.new, &y.name));
                assert_eq!(x.parents, y.parents);
                assert_eq!(x.spec, y.spec);
            }
        }
    }
}
