//! The cascade execution tier — Algorithm 2 as plan → schedule → journal.
//!
//! The paper's automated update cascade (§5) used to live as one serial
//! loop in [`crate::update`]; it is now three layers, each independently
//! testable:
//!
//! 1. **Planning** ([`plan`]) — [`plan_cascade`] performs all graph
//!    mutation up front and emits an immutable [`CascadePlan`]: per-node
//!    parent sets, MTL groups as barrier tasks, skip/terminate decisions
//!    — pure data.
//! 2. **Scheduling** ([`schedule`]) — a ready-queue wavefront scheduler
//!    executes independent plan tasks concurrently on a scoped thread
//!    pool (`mgit cascade --jobs N`); `jobs = 1` reproduces the serial
//!    order (and bit-identical results) of the historical
//!    implementation.
//! 3. **Journaling** ([`journal`]) — per-task completion records under
//!    `.mgit/cascade-journal/` let `mgit cascade --resume` pick up an
//!    interrupted cascade at exactly the unfinished suffix instead of
//!    retraining finished models.
//!
//! Thread-safety contract: [`CreationExecutor`] and [`CheckpointStore`]
//! are `&self + Send + Sync` — one executor and one store are shared by
//! reference across every worker. Parent checkpoints load through the
//! store's (optionally [`crate::delta::ResolveCache`]-backed) `load`, so
//! concurrent workers share resolved ancestor tensors instead of
//! re-materializing them.
//!
//! [`crate::update::run_update_cascade`] remains as the serial
//! single-call convenience wrapper over this module.

pub mod journal;
pub mod plan;
pub mod schedule;

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::delta::StoredModel;
use crate::lineage::{LineageGraph, NodeIdx};
use crate::update::{CascadeReport, CheckpointStore, CreationExecutor};

pub use journal::{journal_dir, journal_exists, load_journal, remove_journal, CascadeJournal};
pub use plan::{plan_cascade, CascadePlan, PlanMember, PlanTask};
pub use schedule::{execute_plan, DoneTasks};

/// Execution knobs for one cascade run.
pub struct CascadeOptions<'a> {
    /// Worker threads for the wavefront scheduler (1 = serial).
    pub jobs: usize,
    /// Journal to append completion records to (None = not resumable).
    pub journal: Option<&'a CascadeJournal>,
}

impl Default for CascadeOptions<'_> {
    fn default() -> Self {
        CascadeOptions { jobs: 1, journal: None }
    }
}

/// Plan and execute a full cascade in one call (Algorithm 2). `m_new`
/// must already be registered as the next version of `m` with a stored
/// checkpoint. See [`plan_cascade`] and [`execute_plan`] for the
/// composable pieces (the CLI uses those directly so it can persist the
/// graph and journal between phases).
#[allow(clippy::too_many_arguments)]
pub fn run(
    g: &mut LineageGraph,
    ckstore: &dyn CheckpointStore,
    exec: &dyn CreationExecutor,
    m: NodeIdx,
    m_new: NodeIdx,
    skip: impl Fn(&LineageGraph, NodeIdx) -> bool,
    terminate: impl Fn(&LineageGraph, NodeIdx) -> bool,
    opts: &CascadeOptions,
) -> Result<CascadeReport> {
    let plan = plan::plan_cascade(g, m, m_new, skip, terminate)?;
    execute_and_apply(g, &plan, ckstore, exec, opts, &DoneTasks::new())
}

/// Execute an already-built plan and apply the results to the graph.
/// `done` holds journal-replayed completions (empty for a fresh run).
pub fn execute_and_apply(
    g: &mut LineageGraph,
    plan: &CascadePlan,
    ckstore: &dyn CheckpointStore,
    exec: &dyn CreationExecutor,
    opts: &CascadeOptions,
    done: &DoneTasks,
) -> Result<CascadeReport> {
    let results =
        schedule::execute_plan(g, plan, ckstore, exec, opts.jobs, opts.journal, done)?;
    apply_results(g, plan, &results, done.len())
}

/// Resume an interrupted, journaled cascade: load the plan and finished
/// prefix from `journal_dir`, execute the unfinished suffix (appending
/// to the same journal), and apply everything to the graph.
pub fn resume(
    g: &mut LineageGraph,
    ckstore: &dyn CheckpointStore,
    exec: &dyn CreationExecutor,
    dir: &Path,
    jobs: usize,
) -> Result<CascadeReport> {
    let (plan, done) = journal::load_journal(dir, g)?;
    let j = CascadeJournal::reopen(dir)?;
    let opts = CascadeOptions { jobs, journal: Some(&j) };
    execute_and_apply(g, &plan, ckstore, exec, &opts, &done)
}

/// Write every completed member's stored model onto its graph node and
/// build the report. Iterates in plan (task) order, so the report is
/// deterministic regardless of completion order.
pub fn apply_results(
    g: &mut LineageGraph,
    plan: &CascadePlan,
    results: &HashMap<NodeIdx, StoredModel>,
    resumed_tasks: usize,
) -> Result<CascadeReport> {
    let mut report = CascadeReport {
        skipped_no_cr: plan.skipped_no_cr.clone(),
        resumed_tasks,
        ..Default::default()
    };
    for task in &plan.tasks {
        for mb in &task.members {
            let sm = results
                .get(&mb.new)
                .ok_or_else(|| anyhow!("cascade produced no result for {}", mb.name))?;
            g.node_mut(mb.new).stored = Some(sm.clone());
            report.new_versions.push((mb.old, mb.new));
        }
    }
    Ok(report)
}
