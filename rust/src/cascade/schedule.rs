//! Scheduling — wavefront execution of a [`CascadePlan`].
//!
//! A ready-queue scheduler over a scoped thread pool: every task whose
//! dependencies are satisfied is *ready*; `jobs` workers pull ready
//! tasks, execute them against the shared `&dyn CreationExecutor` /
//! `&dyn CheckpointStore` (both `Send + Sync` by trait contract), and
//! unblock dependents as they finish. Independent sibling models — the
//! common shape of a lineage graph, where one upstream update fans out
//! into many finetuned children — retrain concurrently instead of one
//! at a time.
//!
//! * With `jobs = 1` the single worker drains the queue FIFO, which is
//!   exactly the all-parents-first serial order of Algorithm 2 — results
//!   are bit-identical to the historical serial implementation.
//! * MTL groups are single barrier tasks: the whole group trains once
//!   through [`CreationExecutor::execute_mtl_group`] on one worker.
//! * On a task failure the first error is kept, no new tasks are issued,
//!   in-flight tasks finish (and are journaled), and the error is
//!   returned — `mgit cascade --resume` replays only the unfinished
//!   suffix.
//!
//! The graph is *never mutated* here; workers read it only for
//! pre-existing checkpoint pointers. Results are applied back onto the
//! graph by [`crate::cascade::apply_results`] after the wavefront
//! drains.

use std::collections::{HashMap, VecDeque};
use std::sync::{Condvar, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::checkpoint::Checkpoint;
use crate::delta::StoredModel;
use crate::lineage::{LineageGraph, NodeIdx};
use crate::registry::CreationSpec;
use crate::update::{CheckpointStore, CreationExecutor};

use super::journal::CascadeJournal;
use super::plan::{CascadePlan, PlanTask};

// Process-global scheduler metrics (`mgit serve` exposes them via
// `GET /metrics`). Updated only at points where the scheduler already
// holds its own lock or is outside any lock — a relaxed atomic op each,
// never a new mutex acquisition.
static TASK_MICROS: crate::obs::LazyHistogram =
    crate::obs::LazyHistogram::new("cascade.task_micros");
static QUEUE_DEPTH: crate::obs::LazyGauge =
    crate::obs::LazyGauge::new("cascade.queue_depth");
static TASKS_DONE: crate::obs::LazyCounter =
    crate::obs::LazyCounter::new("cascade.tasks_completed");

/// Completed-task results as replayed from a journal: task id -> the
/// stored models of every member.
pub type DoneTasks = HashMap<usize, Vec<(NodeIdx, StoredModel)>>;

struct SchedState {
    ready: VecDeque<usize>,
    indeg: Vec<usize>,
    /// Tasks not yet finished (neither done-from-journal nor executed).
    remaining: usize,
    /// Tasks currently executing on some worker.
    running: usize,
    /// Stored models of every completed new node (seeded from `done`).
    results: HashMap<NodeIdx, StoredModel>,
    /// First failure; once set, no new tasks are issued.
    error: Option<anyhow::Error>,
}

/// Execute every task of `plan` not already in `done`, fanning out over
/// `jobs` worker threads. Returns the stored model of every new node
/// (journal-replayed ones included).
pub fn execute_plan(
    g: &LineageGraph,
    plan: &CascadePlan,
    ckstore: &dyn CheckpointStore,
    exec: &dyn CreationExecutor,
    jobs: usize,
    journal: Option<&CascadeJournal>,
    done: &DoneTasks,
) -> Result<HashMap<NodeIdx, StoredModel>> {
    let n_tasks = plan.tasks.len();
    let mut results: HashMap<NodeIdx, StoredModel> = HashMap::new();
    for outs in done.values() {
        for (idx, sm) in outs {
            results.insert(*idx, sm.clone());
        }
    }
    // Effective in-degrees ignore dependencies already satisfied by the
    // journal replay.
    let indeg: Vec<usize> = plan
        .tasks
        .iter()
        .map(|t| t.deps.iter().filter(|&d| !done.contains_key(d)).count())
        .collect();
    let ready: VecDeque<usize> = (0..n_tasks)
        .filter(|t| !done.contains_key(t) && indeg[*t] == 0)
        .collect();
    let remaining = n_tasks - done.len();
    if remaining == 0 {
        return Ok(results);
    }

    let state = Mutex::new(SchedState {
        ready,
        indeg,
        remaining,
        running: 0,
        results,
        error: None,
    });
    let cv = Condvar::new();

    let workers = jobs.max(1).min(remaining);
    if workers <= 1 {
        worker(g, plan, ckstore, exec, journal, &state, &cv);
    } else {
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| worker(g, plan, ckstore, exec, journal, &state, &cv));
            }
        });
    }

    let st = state.into_inner().unwrap();
    if let Some(e) = st.error {
        return Err(e);
    }
    if st.remaining > 0 {
        bail!("cascade scheduler exited with {} tasks unfinished", st.remaining);
    }
    Ok(st.results)
}

fn worker(
    g: &LineageGraph,
    plan: &CascadePlan,
    ckstore: &dyn CheckpointStore,
    exec: &dyn CreationExecutor,
    journal: Option<&CascadeJournal>,
    state: &Mutex<SchedState>,
    cv: &Condvar,
) {
    loop {
        let tid = {
            let mut st = state.lock().unwrap();
            loop {
                if st.error.is_some() || st.remaining == 0 {
                    return;
                }
                if let Some(t) = st.ready.pop_front() {
                    st.running += 1;
                    QUEUE_DEPTH.set(st.ready.len() as i64);
                    break t;
                }
                if st.running == 0 {
                    // Nothing ready, nothing in flight, work remaining:
                    // unreachable for an acyclic plan, but fail loudly
                    // rather than deadlock if an invariant ever breaks.
                    st.error = Some(anyhow!(
                        "cascade scheduler stalled with {} tasks blocked",
                        st.remaining
                    ));
                    cv.notify_all();
                    return;
                }
                st = cv.wait(st).unwrap();
            }
        };

        let task = &plan.tasks[tid];
        let started = std::time::Instant::now();
        let outcome = run_task(g, task, ckstore, exec, state).and_then(|outs| {
            // Journal outside the scheduler lock: the record is a write +
            // fsync, and serializing every worker behind it would bend
            // wide wavefronts back toward serial. The journal's own file
            // mutex keeps appends whole.
            if let Some(j) = journal {
                j.record(g, tid, &outs).context("writing cascade journal")?;
            }
            Ok(outs)
        });

        let mut st = state.lock().unwrap();
        st.running -= 1;
        match outcome {
            Ok(outs) => {
                TASK_MICROS.observe(started.elapsed().as_micros() as u64);
                TASKS_DONE.inc();
                for (idx, sm) in outs {
                    st.results.insert(idx, sm);
                }
                for &dep in &task.dependents {
                    st.indeg[dep] -= 1;
                    if st.indeg[dep] == 0 {
                        st.ready.push_back(dep);
                    }
                }
                st.remaining -= 1;
                QUEUE_DEPTH.set(st.ready.len() as i64);
                cv.notify_all();
            }
            Err(e) => {
                if st.error.is_none() {
                    st.error = Some(e.context(format!(
                        "cascade task `{}` failed",
                        task.members[task.parent_source].name
                    )));
                }
                cv.notify_all();
                return;
            }
        }
    }
}

/// Execute one task: load parent checkpoints (completed-in-cascade
/// parents come from the results map, everything else from the graph),
/// run the creation function(s), and persist each member against its
/// previous version.
fn run_task(
    g: &LineageGraph,
    task: &PlanTask,
    ckstore: &dyn CheckpointStore,
    exec: &dyn CreationExecutor,
    state: &Mutex<SchedState>,
) -> Result<Vec<(NodeIdx, StoredModel)>> {
    let src = &task.members[task.parent_source];
    // Snapshot the parent pointers under the lock, then do all I/O and
    // compute outside it.
    let parent_sms: Vec<StoredModel> = {
        let st = state.lock().unwrap();
        src.parents
            .iter()
            .map(|&p| match st.results.get(&p) {
                Some(sm) => Ok(sm.clone()),
                None => g
                    .node(p)
                    .stored
                    .clone()
                    .ok_or_else(|| anyhow!("parent {} has no checkpoint", g.node(p).name)),
            })
            .collect::<Result<_>>()?
    };
    let parents: Vec<Checkpoint> = parent_sms
        .iter()
        .map(|sm| ckstore.load(sm))
        .collect::<Result<_>>()?;

    let mut outs = Vec::with_capacity(task.members.len());
    if task.mtl {
        let specs: Vec<&CreationSpec> = task.members.iter().map(|mb| &mb.spec).collect();
        let cks = exec.execute_mtl_group(&specs, &src.arch, &parents)?;
        if cks.len() != task.members.len() {
            bail!(
                "MTL executor returned {} models for {} members",
                cks.len(),
                task.members.len()
            );
        }
        for (mb, ck) in task.members.iter().zip(&cks) {
            outs.push(save_member(g, ckstore, mb.old, mb.new, ck)?);
        }
    } else {
        let ck = exec.execute(&src.spec, &src.arch, &parents)?;
        outs.push(save_member(g, ckstore, src.old, src.new, &ck)?);
    }
    Ok(outs)
}

/// Persist one member's checkpoint, delta-compressing against its
/// previous version when that version has a stored checkpoint.
fn save_member(
    g: &LineageGraph,
    ckstore: &dyn CheckpointStore,
    old: NodeIdx,
    new: NodeIdx,
    ck: &Checkpoint,
) -> Result<(NodeIdx, StoredModel)> {
    let prev_data = match &g.node(old).stored {
        Some(sm) => Some((sm.clone(), ckstore.load(sm)?)),
        None => None,
    };
    let sm = ckstore
        .save(ck, prev_data.as_ref().map(|(s, c)| (s, c)))
        .with_context(|| format!("storing {}", g.node(new).name))?;
    Ok((new, sm))
}
