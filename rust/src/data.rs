//! Synthetic workload substrate (the GLUE / perturbed-GLUE / corpus
//! substitute — see DESIGN.md §2).
//!
//! * A Markov-chain "language" over the 254-token data vocabulary gives
//!   masked-language-modeling real signal (neighbors predict the masked
//!   token).
//! * Nine rule-based sequence-classification tasks (`task1`…`task9`)
//!   stand in for the GLUE suite; labels are deterministic functions of
//!   the token sequence so accuracy is a meaningful, learnable metric.
//! * Ten parametric perturbation families replicate the Moradi–Samwald
//!   robustness perturbations used by the paper's G2 versions.
//!
//! All generation is deterministic in (task, split seed, batch index), so
//! "datasets" need no storage and every experiment is reproducible.

use anyhow::{anyhow, Result};

use crate::util::rng::Rng;

/// Data-vocabulary size (ids 0..=253; 254 = CLS, 255 = MASK).
pub const DATA_VOCAB: i32 = 254;
pub const MASK_TOKEN: i32 = 255;
pub const IGNORE_LABEL: i32 = -100;

/// The nine classification tasks.
pub const TASKS: [&str; 9] = [
    "task1", "task2", "task3", "task4", "task5", "task6", "task7", "task8", "task9",
];

/// The ten perturbation families (G2 creates one model version per kind).
pub const PERTURBATIONS: [&str; 10] = [
    "swap", "drop", "dup", "remap", "mask_noise", "shift", "window_shuffle",
    "reverse", "uniform_noise", "crop",
];

/// A batch of sequences + labels.
#[derive(Debug, Clone)]
pub struct Batch {
    /// B*T token ids, row-major.
    pub tokens: Vec<i32>,
    /// CLS: B labels. MLM: B*T labels with IGNORE_LABEL on unmasked slots.
    pub labels: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

/// Markov-chain token sampler: per-state preferred step pattern makes
/// neighbors informative for MLM.
fn sample_sequence(rng: &mut Rng, seq: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(seq);
    let mut cur = rng.below(DATA_VOCAB as u64) as i32;
    out.push(cur);
    for _ in 1..seq {
        // Mostly a deterministic walk (+1, +3 or +7 depending on state
        // class), occasionally a random jump.
        let step = match cur % 3 {
            0 => 1,
            1 => 3,
            _ => 7,
        };
        cur = if rng.bool_with(0.15) {
            rng.below(DATA_VOCAB as u64) as i32
        } else {
            (cur + step) % DATA_VOCAB
        };
        out.push(cur);
    }
    out
}

/// Deterministic label rule per task; all rules map into {0..3} (or
/// {0,1}); they span "easy" (first-token class) to "hard" (counting).
pub fn label_rule(task: &str, seq: &[i32]) -> Result<i32> {
    let n = seq.len() as i64;
    let sum: i64 = seq.iter().map(|&t| t as i64).sum();
    Ok(match task {
        // mean-token quartile
        "task1" => ((sum / n) * 4 / DATA_VOCAB as i64).min(3) as i32,
        // presence of any token < 32 in the first half
        "task2" => seq[..seq.len() / 2].iter().any(|&t| t < 32) as i32,
        // max-token quartile
        "task3" => {
            let m = *seq.iter().max().unwrap() as i64;
            (m * 4 / DATA_VOCAB as i64).min(3) as i32
        }
        // first-token quartile
        "task4" => (seq[0] as i64 * 4 / DATA_VOCAB as i64).min(3) as i32,
        // parity classes of the count of even tokens
        "task5" => ((seq.iter().filter(|&&t| t % 2 == 0).count()) % 4) as i32,
        // which half has the larger sum
        "task6" => {
            let half = seq.len() / 2;
            let a: i64 = seq[..half].iter().map(|&t| t as i64).sum();
            let b: i64 = seq[half..].iter().map(|&t| t as i64).sum();
            (a > b) as i32
        }
        // last-token quartile
        "task7" => (seq[seq.len() - 1] as i64 * 4 / DATA_VOCAB as i64).min(3) as i32,
        // quartile of the position of the maximum token
        "task8" => {
            let pos = seq
                .iter()
                .enumerate()
                .max_by_key(|(i, &t)| (t, std::cmp::Reverse(*i)))
                .unwrap()
                .0;
            ((pos * 4) / seq.len()).min(3) as i32
        }
        // min-token quartile
        "task9" => {
            let m = *seq.iter().min().unwrap() as i64;
            (m * 4 / DATA_VOCAB as i64).min(3) as i32
        }
        other => return Err(anyhow!("unknown task `{other}`")),
    })
}

fn batch_rng(task: &str, split_seed: u64, index: u64) -> Rng {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in task.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    Rng::new(h ^ split_seed.wrapping_mul(0x9E3779B97F4A7C15) ^ index.rotate_left(17))
}

/// Generate a classification batch for `task`.
pub fn cls_batch(
    task: &str,
    batch: usize,
    seq: usize,
    split_seed: u64,
    index: u64,
    perturb: Option<(&str, f64)>,
) -> Result<Batch> {
    let mut rng = batch_rng(task, split_seed, index);
    let mut tokens = Vec::with_capacity(batch * seq);
    let mut labels = Vec::with_capacity(batch);
    for row in 0..batch {
        let mut s = sample_sequence(&mut rng, seq);
        // Labels are computed BEFORE perturbation: a robust model must
        // predict the clean label from the perturbed input.
        labels.push(label_rule(task, &s)?);
        if let Some((kind, strength)) = perturb {
            // Independent stream: perturbation must not consume from the
            // data RNG, so clean and perturbed batches share sequences.
            let mut prng =
                batch_rng(task, split_seed ^ 0x5045_5254, index * 131 + row as u64);
            perturb_sequence(&mut s, kind, strength, &mut prng)?;
        }
        tokens.extend_from_slice(&s);
    }
    Ok(Batch { tokens, labels, batch, seq })
}

/// Generate an MLM batch from the corpus (15% masking).
pub fn mlm_batch(
    corpus_seed: u64,
    batch: usize,
    seq: usize,
    index: u64,
    perturb: Option<(&str, f64)>,
) -> Result<Batch> {
    let mut rng = batch_rng("corpus", corpus_seed, index);
    let mut tokens = Vec::with_capacity(batch * seq);
    let mut labels = Vec::with_capacity(batch * seq);
    for row in 0..batch {
        let mut s = sample_sequence(&mut rng, seq);
        if let Some((kind, strength)) = perturb {
            let mut prng =
                batch_rng("corpus", corpus_seed ^ 0x5045_5254, index * 131 + row as u64);
            perturb_sequence(&mut s, kind, strength, &mut prng)?;
        }
        for &t in &s {
            if rng.bool_with(0.15) {
                tokens.push(MASK_TOKEN);
                labels.push(t);
            } else {
                tokens.push(t);
                labels.push(IGNORE_LABEL);
            }
        }
    }
    Ok(Batch { tokens, labels, batch, seq })
}

/// Apply one perturbation family in place. `strength` ∈ [0,1].
pub fn perturb_sequence(
    seq: &mut [i32],
    kind: &str,
    strength: f64,
    rng: &mut Rng,
) -> Result<()> {
    let n = seq.len();
    match kind {
        "swap" => {
            for i in 0..n - 1 {
                if rng.bool_with(strength) {
                    seq.swap(i, i + 1);
                }
            }
        }
        "drop" => {
            // Dropped tokens are replaced by the sequence's previous token
            // (length must stay fixed for the AOT shapes).
            for i in 1..n {
                if rng.bool_with(strength) {
                    seq[i] = seq[i - 1];
                }
            }
        }
        "dup" => {
            let mut i = n - 1;
            while i > 0 {
                if rng.bool_with(strength) {
                    seq[i] = seq[i - 1];
                }
                i -= 1;
            }
        }
        "remap" => {
            // Systematic token remap (like a casing change): t -> t XOR 1.
            for t in seq.iter_mut() {
                if rng.bool_with(strength) {
                    *t = (*t ^ 1).min(DATA_VOCAB - 1);
                }
            }
        }
        "mask_noise" => {
            for t in seq.iter_mut() {
                if rng.bool_with(strength * 0.5) {
                    *t = MASK_TOKEN;
                }
            }
        }
        "shift" => {
            for t in seq.iter_mut() {
                if rng.bool_with(strength) {
                    *t = (*t + 1) % DATA_VOCAB;
                }
            }
        }
        "window_shuffle" => {
            let w = 4.min(n);
            for start in (0..n - w).step_by(w) {
                if rng.bool_with(strength) {
                    rng.shuffle(&mut seq[start..start + w]);
                }
            }
        }
        "reverse" => {
            let w = 4.min(n);
            for start in (0..n - w).step_by(w) {
                if rng.bool_with(strength) {
                    seq[start..start + w].reverse();
                }
            }
        }
        "uniform_noise" => {
            for t in seq.iter_mut() {
                if rng.bool_with(strength) {
                    *t = rng.below(DATA_VOCAB as u64) as i32;
                }
            }
        }
        "crop" => {
            // Zero out a suffix (like truncation with padding).
            let keep = n - ((n as f64 * strength * 0.5) as usize).min(n / 2);
            for t in seq[keep..].iter_mut() {
                *t = 0;
            }
        }
        other => return Err(anyhow!("unknown perturbation `{other}`")),
    }
    Ok(())
}

/// Silo view for federated learning: only sequences whose label falls in
/// the silo's label subset (rejection sampling), modeling per-silo label
/// skew over the shared task.
pub fn silo_cls_batch(
    task: &str,
    batch: usize,
    seq: usize,
    split_seed: u64,
    index: u64,
    allowed_labels: &[i32],
) -> Result<Batch> {
    let mut rng = batch_rng(task, split_seed, index ^ 0x51105110);
    let mut tokens = Vec::with_capacity(batch * seq);
    let mut labels = Vec::with_capacity(batch);
    let mut guard = 0;
    while labels.len() < batch {
        let s = sample_sequence(&mut rng, seq);
        let l = label_rule(task, &s)?;
        guard += 1;
        if allowed_labels.contains(&l) || guard > batch * 1000 {
            labels.push(l);
            tokens.extend_from_slice(&s);
        }
    }
    Ok(Batch { tokens, labels, batch, seq })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_deterministic() {
        let a = cls_batch("task1", 8, 16, 0, 3, None).unwrap();
        let b = cls_batch("task1", 8, 16, 0, 3, None).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.labels, b.labels);
        let c = cls_batch("task1", 8, 16, 0, 4, None).unwrap();
        assert_ne!(a.tokens, c.tokens);
        let d = cls_batch("task2", 8, 16, 0, 3, None).unwrap();
        assert_ne!(a.tokens, d.tokens);
    }

    #[test]
    fn all_tasks_produce_valid_labels() {
        for task in TASKS {
            let b = cls_batch(task, 32, 32, 1, 0, None).unwrap();
            assert_eq!(b.labels.len(), 32);
            assert_eq!(b.tokens.len(), 32 * 32);
            assert!(b.labels.iter().all(|&l| (0..4).contains(&l)), "{task}");
            assert!(b.tokens.iter().all(|&t| (0..DATA_VOCAB).contains(&t)));
            // labels not all identical (task carries signal)
            let first = b.labels[0];
            assert!(
                b.labels.iter().any(|&l| l != first),
                "{task} produced constant labels"
            );
        }
    }

    #[test]
    fn mlm_masking_fraction() {
        let b = mlm_batch(7, 16, 32, 0, None).unwrap();
        let masked = b.tokens.iter().filter(|&&t| t == MASK_TOKEN).count();
        let frac = masked as f64 / b.tokens.len() as f64;
        assert!((0.08..0.25).contains(&frac), "mask frac {frac}");
        for (t, l) in b.tokens.iter().zip(&b.labels) {
            if *t == MASK_TOKEN {
                assert!((0..DATA_VOCAB).contains(l));
            } else {
                assert_eq!(*l, IGNORE_LABEL);
            }
        }
    }

    #[test]
    fn perturbations_all_valid_and_bounded() {
        for kind in PERTURBATIONS {
            let clean = cls_batch("task1", 8, 32, 0, 0, None).unwrap();
            let pert = cls_batch("task1", 8, 32, 0, 0, Some((kind, 0.3))).unwrap();
            assert_eq!(pert.tokens.len(), clean.tokens.len(), "{kind}");
            assert!(
                pert.tokens
                    .iter()
                    .all(|&t| (0..DATA_VOCAB).contains(&t) || t == MASK_TOKEN),
                "{kind} emitted invalid tokens"
            );
            // Labels computed pre-perturbation: equal to clean labels.
            assert_eq!(pert.labels, clean.labels, "{kind}");
        }
        // strength 0 = identity
        let clean = cls_batch("task3", 4, 16, 0, 0, None).unwrap();
        let zero = cls_batch("task3", 4, 16, 0, 0, Some(("swap", 0.0))).unwrap();
        assert_eq!(clean.tokens, zero.tokens);
    }

    #[test]
    fn unknown_names_rejected() {
        assert!(cls_batch("nope", 2, 4, 0, 0, None).is_err());
        let mut s = vec![1, 2, 3, 4];
        let mut rng = Rng::new(0);
        assert!(perturb_sequence(&mut s, "nope", 0.5, &mut rng).is_err());
    }

    #[test]
    fn silo_batches_respect_label_subset() {
        let b = silo_cls_batch("task4", 16, 16, 0, 2, &[1, 2]).unwrap();
        assert!(b.labels.iter().all(|&l| l == 1 || l == 2), "{:?}", b.labels);
    }

    #[test]
    fn markov_structure_is_predictable() {
        // Verify the corpus has learnable structure: the deterministic-step
        // transition holds much more often than chance.
        let mut rng = Rng::new(5);
        let mut hits = 0;
        let mut total = 0;
        for _ in 0..200 {
            let s = sample_sequence(&mut rng, 32);
            for w in s.windows(2) {
                let step = match w[0] % 3 {
                    0 => 1,
                    1 => 3,
                    _ => 7,
                };
                if w[1] == (w[0] + step) % DATA_VOCAB {
                    hits += 1;
                }
                total += 1;
            }
        }
        let frac = hits as f64 / total as f64;
        assert!(frac > 0.7, "markov hit rate {frac}");
    }
}
