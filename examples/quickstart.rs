//! Quickstart: the MGit lifecycle in one file.
//!
//! Creates a repository, trains a small MLM base model and a finetuned
//! child through the AOT-compiled runtime, registers both in the lineage
//! graph, diffs them, delta-compresses the child against the parent, and
//! registers + runs a test — everything a downstream user touches first.
//!
//! Run: `cargo run --release --example quickstart` (needs `make artifacts`)

use std::path::Path;

use mgit::checkpoint::Checkpoint;
use mgit::cli::Repo;
use mgit::delta::{self, CompressConfig};
use mgit::diff::divergence_scores;
use mgit::modeldag::ModelDag;
use mgit::registry::{CreationSpec, FreezeSpec, Objective, TestScope, TestSpec};
use mgit::runtime::Runtime;
use mgit::train::Trainer;
use mgit::update::CreationExecutor;
use mgit::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(Path::new("artifacts"))?;
    let zoo = rt.zoo();
    let dir = std::env::temp_dir().join("mgit-quickstart");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    let mut repo = Repo::init(&dir)?;
    println!("== initialized repo at {}", dir.display());

    // 1. Train a base model (MLM pretraining) and register it.
    let arch = "tx-tiny";
    let spec = zoo.arch(arch)?;
    let trainer = Trainer::new(&rt);
    let base_cr = CreationSpec::Pretrain { corpus_seed: 1, steps: 40, lr: 0.02 };
    let base_ck = trainer.execute(&base_cr, arch, &[Checkpoint::init(spec, 1)])?;
    let (base_sm, _) = delta::store_raw(&repo.store, spec, &base_ck)?;
    let base = repo.graph.add_node("base", arch)?;
    repo.graph.node_mut(base).stored = Some(base_sm.clone());
    repo.graph.register_creation_function(base, base_cr)?;
    println!("== trained + registered `base` ({} params)", spec.param_count);

    // 2. Finetune a child on a classification task.
    let child_cr = CreationSpec::Finetune {
        task: "task4".into(),
        objective: Objective::Cls,
        steps: 200,
        lr: 0.02,
        seed: 2,
        freeze: FreezeSpec::None,
        perturb: None,
    };
    let child_ck = trainer.execute(&child_cr, arch, &[base_ck.clone()])?;
    let child = repo.graph.add_node("task4-model", arch)?;
    repo.graph.register_creation_function(child, child_cr)?;
    repo.graph.add_edge(base, child)?;

    // 3. Diff parent vs child.
    let parent_dag = ModelDag::from_arch(spec, Some(&base_sm))?;

    // 4. Delta-compress the child against the parent (Algorithm 1) —
    //    accepted only if it saves space AND accuracy survives.
    let (child_sm, stored_ck, report, accepted) = delta::delta_compress_checked(
        &repo.store,
        spec,
        &child_ck,
        spec,
        &base_ck,
        &base_sm,
        CompressConfig::default(),
        &rt,
        |rec| {
            let (_, acc_rec) = rt.eval_many(arch, Objective::Cls, &rec.flat, "task4", 0, 2)?;
            let (_, acc_org) =
                rt.eval_many(arch, Objective::Cls, &child_ck.flat, "task4", 0, 2)?;
            Ok(acc_org - acc_rec <= 0.01)
        },
    )?;
    repo.graph.node_mut(child).stored = Some(child_sm.clone());
    println!(
        "== delta compression {}: {} raw -> {} stored ({:.2}x), max |err| {:.2e}",
        if accepted { "ACCEPTED" } else { "rejected" },
        human_bytes(report.raw_bytes),
        human_bytes(report.stored_bytes),
        report.raw_bytes as f64 / report.stored_bytes.max(1) as f64,
        report.max_abs_err,
    );
    let child_dag = ModelDag::from_arch(spec, Some(&child_sm))?;
    let (ds, dc) = divergence_scores(&parent_dag, &child_dag);
    println!("== diff(base, task4-model): structural {ds:.3}, contextual {dc:.3}");

    // 5. Register a test + run it over the graph.
    repo.graph.tests.register(
        "acc/task4",
        TestScope::Node("task4-model".into()),
        TestSpec::EvalAccuracy {
            task: "task4".into(),
            objective: Objective::Cls,
            batches: 3,
            split_seed: 0,
            min_acc: 0.5,
        },
    )?;
    let (pass, metric) = mgit::registry::run_test(
        &repo.graph.tests.tests[0].spec,
        &stored_ck,
        &rt,
    )?;
    println!("== test acc/task4: {} (accuracy {metric:.3})", if pass { "PASS" } else { "FAIL" });

    repo.save()?;
    println!("== saved lineage graph to {}", Repo::graph_path(&dir).display());
    println!("try: target/release/mgit log --dir {}", dir.display());
    Ok(())
}
