//! G1: automated lineage construction over a "model hub" pool (§3.2).
//!
//! Builds the 23-model zoo (10 independently pretrained roots + 13
//! finetuned / frozen children mirroring the paper's HuggingFace list),
//! then reconstructs the lineage graph *without any annotations* using
//! the diff-based auto-insertion algorithm, and scores it against the
//! gold parent map (paper: 22/23 correct).
//!
//! Run: `cargo run --release --example model_hub [small]`

use std::path::Path;

use mgit::autoconstruct::AutoConfig;
use mgit::runtime::Runtime;
use mgit::store::Store;
use mgit::util::human_secs;
use mgit::workloads::{self, Scale};

fn main() -> anyhow::Result<()> {
    let small = std::env::args().any(|a| a == "small");
    let mut scale = if small { Scale::small() } else { Scale::paper() };
    if small {
        scale.pretrain_steps = 6;
        scale.g1_child_steps = 6;
    }
    let rt = Runtime::new(Path::new("artifacts"))?;

    println!("training the 23-model zoo (this is the slow part)…");
    let t = mgit::util::timing::Timer::start();
    let wl = workloads::build_g1(&rt, &scale)?;
    println!("zoo built in {}", human_secs(t.elapsed_secs()));

    let gold = workloads::g1_gold();
    let order: Vec<(String, String, Option<String>)> = gold
        .iter()
        .map(|(n, a, p)| (n.to_string(), a.to_string(), p.map(String::from)))
        .collect();

    let store = Store::in_memory();
    let (g, correct, times) = workloads::auto_construct(
        &rt,
        &store,
        &order,
        &wl.checkpoints,
        &AutoConfig::default(),
    )?;

    println!("\nauto-constructed lineage:");
    for node in &g.nodes {
        let parents: Vec<&str> =
            node.prov_parents.iter().map(|&p| g.node(p).name.as_str()).collect();
        let gold_parent = gold.iter().find(|(n, _, _)| *n == node.name).unwrap().2;
        let got = parents.first().copied();
        let mark = if got == gold_parent { "✓" } else { "✗" };
        println!("  {mark} {:<40} <- {:?}", node.name, got.unwrap_or("(root)"));
    }
    println!(
        "\ncorrectly inserted: {}/{} (paper: 22/23)",
        correct,
        gold.len()
    );
    let avg = times.iter().sum::<f64>() / times.len() as f64;
    println!("avg per-model insertion time: {}", human_secs(avg));
    Ok(())
}
