//! G3: federated learning with lineage tracking.
//!
//! Runs the FL controller (40 label-skewed silos, sampled workers,
//! FedAvg) with every worker/global model registered in the lineage
//! graph and delta-compressed against the round's global model, then
//! reports per-round held-out accuracy and the storage footprint.
//!
//! Run: `cargo run --release --example federated [small]`

use std::path::Path;

use mgit::fl::{run_federated, FlConfig};
use mgit::lineage::LineageGraph;
use mgit::runtime::Runtime;
use mgit::store::Store;
use mgit::train::CasCheckpointStore;
use mgit::util::human_bytes;

fn main() -> anyhow::Result<()> {
    let small = std::env::args().any(|a| a == "small");
    let rt = Runtime::new(Path::new("artifacts"))?;
    let store = Store::in_memory();
    let ckstore = CasCheckpointStore {
        store: &store,
        zoo: rt.zoo(),
        kernel: &mgit::delta::NativeKernel,
        compress: Some(Default::default()),
        cache: None,
    };
    let cfg = if small {
        FlConfig { n_silos: 8, workers_per_round: 3, rounds: 3, local_steps: 2, ..Default::default() }
    } else {
        FlConfig { n_silos: 40, workers_per_round: 5, rounds: 10, local_steps: 3, ..Default::default() }
    };
    println!(
        "federated: {} silos, {}/round sampled, {} rounds, {} local steps",
        cfg.n_silos, cfg.workers_per_round, cfg.rounds, cfg.local_steps
    );
    let mut g = LineageGraph::new();
    let rounds = run_federated(&rt, &mut g, &ckstore, &cfg)?;
    for r in &rounds {
        println!(
            "round {:>2}: sampled silos {:?}, global accuracy {:.3}",
            r.round, r.sampled, r.eval_acc
        );
    }
    let (prov, ver) = g.edge_counts();
    println!("\nlineage: {} nodes / {} prov + {} ver edges", g.len(), prov, ver);
    let spec = rt.zoo().arch(&cfg.arch)?;
    let raw = (g.len() * spec.param_count * 4) as u64;
    let stored = store.stored_bytes()?;
    println!(
        "storage: {} raw across models -> {} stored ({:.2}x)",
        human_bytes(raw),
        human_bytes(stored),
        raw as f64 / stored.max(1) as f64
    );
    let first = rounds.first().map(|r| r.eval_acc).unwrap_or(0.0);
    let last = rounds.last().map(|r| r.eval_acc).unwrap_or(0.0);
    println!("accuracy: round1 {first:.3} -> final {last:.3}");
    g.integrity_check()?;
    Ok(())
}
