//! G4: specialization to edge devices via progressive magnitude pruning.
//!
//! For three architectures (the ResNet/DenseNet/MobileNet analogs of the
//! zoo), trains a dense task model, prunes it to increasing sparsities
//! with recovery finetuning (the paper's two-step G4 process), stores the
//! chain with sparsity-preserving pre-quantized deltas, and verifies the
//! sparsity invariant through a registered test.
//!
//! Run: `cargo run --release --example edge_pruning [small]`

use std::path::Path;

use mgit::delta::{Codec, CompressConfig};
use mgit::registry::{run_test, Objective, TestSpec};
use mgit::runtime::Runtime;
use mgit::store::Store;
use mgit::util::human_bytes;
use mgit::workloads::{self, PersistMode, Scale};

fn main() -> anyhow::Result<()> {
    let small = std::env::args().any(|a| a == "small");
    let mut scale = if small { Scale::small() } else { Scale::paper() };
    if small {
        scale.sparsities = vec![0.5, 0.8];
    }
    let rt = Runtime::new(Path::new("artifacts"))?;
    let zoo = rt.zoo().clone();

    let mut wl = workloads::build_g4(&rt, &scale)?;
    println!("built G4: {} nodes", wl.graph.len());

    // Report accuracy + sparsity along each pruning chain.
    println!("\n{:<28} {:>9} {:>9}", "model", "sparsity", "accuracy");
    for node in &wl.graph.nodes {
        let ck = wl.ck(&node.name)?;
        let task = node
            .creation
            .as_ref()
            .and_then(|c| match c {
                mgit::registry::CreationSpec::Finetune { task, .. }
                | mgit::registry::CreationSpec::Prune { task, .. } => Some(task.clone()),
                _ => None,
            })
            .unwrap_or_else(|| "task1".into());
        let (_, acc) = rt.eval_many(&ck.arch, Objective::Cls, &ck.flat, &task, 0, 2)?;
        println!("{:<28} {:>8.1}% {:>9.3}", node.name, ck.sparsity() * 100.0, acc);
    }

    // Persist with the G4 config: pre-quantized deltas preserve sparsity.
    let store = Store::in_memory();
    let cfg = CompressConfig { eps: 1e-4, codec: Codec::Deflate, prequantize: true };
    let report = workloads::persist(
        &mut wl,
        &store,
        &zoo,
        &rt,
        PersistMode::Delta(cfg),
        |_, _| Ok(true),
    )?;
    println!(
        "\nstored {} models: {} -> {} ({:.2}x)",
        report.n_models,
        human_bytes(report.raw_bytes),
        human_bytes(report.stored_bytes),
        report.ratio()
    );

    // Verify sparsity survives the storage round-trip (paper's G4 check).
    for node in &wl.graph.nodes {
        if !node.name.contains("sparse") {
            continue;
        }
        let sm = node.stored.as_ref().unwrap();
        let loaded = mgit::delta::load(&store, &zoo, sm, &rt)?;
        let want = wl.ck(&node.name)?.sparsity();
        let got = loaded.sparsity();
        let (pass, metric) = run_test(
            &TestSpec::SparsityAtLeast { min: want - 1e-6 },
            &loaded,
            &rt,
        )?;
        println!(
            "sparsity roundtrip {:<26} built {:.3} loaded {:.3} -> {}",
            node.name,
            want,
            metric.max(got),
            if pass { "PRESERVED" } else { "LOST" }
        );
        assert!(pass, "sparsity lost for {}", node.name);
    }
    Ok(())
}
