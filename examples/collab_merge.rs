//! Collaboration: two users edit one model concurrently; MGit's `merge`
//! primitive (Figure 2) classifies the combination.
//!
//! * Alice finetunes only the classification head (frozen backbone);
//! * Bob BitFit-tunes the bias/LN vectors of the backbone;
//! * a third user edits the same head as Alice → hard conflict.
//!
//! Run: `cargo run --release --example collab_merge`

use std::path::Path;

use mgit::checkpoint::Checkpoint;
use mgit::merge::{merge, MergeOutcome};
use mgit::modeldag::ModelDag;
use mgit::registry::{CreationSpec, FreezeSpec, Objective};
use mgit::runtime::Runtime;
use mgit::train::Trainer;
use mgit::update::CreationExecutor;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(Path::new("artifacts"))?;
    let arch = "tx-tiny";
    let spec = rt.zoo().arch(arch)?;
    let dag = ModelDag::from_arch(spec, None)?;
    let trainer = Trainer::new(&rt);

    // Shared starting point.
    let base = trainer.execute(
        &CreationSpec::Pretrain { corpus_seed: 9, steps: 30, lr: 0.02 },
        arch,
        &[Checkpoint::init(spec, 9)],
    )?;

    let finetune = |task: &str, freeze: FreezeSpec, seed: u64| CreationSpec::Finetune {
        task: task.into(),
        objective: Objective::Cls,
        steps: 25,
        lr: 0.02,
        seed,
        freeze,
        perturb: None,
    };

    // Alice: heads only. Bob: biases only (BitFit). Carol: heads again.
    let alice = trainer.execute(&finetune("task1", FreezeSpec::Backbone, 1), arch, &[base.clone()])?;
    let bob = trainer.execute(&finetune("task2", FreezeSpec::BiasOnly, 2), arch, &[base.clone()])?;
    let carol = trainer.execute(&finetune("task3", FreezeSpec::Backbone, 3), arch, &[base.clone()])?;

    // Alice + Bob: disjoint layers, but biases feed the heads → the
    // decision tree lands on "possible conflict" and asks for tests.
    let out = merge(spec, &dag, &base, &alice, &bob)?;
    println!("alice + bob   -> {}", out.verdict());
    if let MergeOutcome::PossibleConflict { merged, dependent_pairs } = &out {
        println!("  dependent pairs (first 3): {:?}", &dependent_pairs[..dependent_pairs.len().min(3)]);
        // Verify with tests: merged model must still do both tasks.
        for task in ["task1", "task2"] {
            let (_, acc) = rt.eval_many(arch, Objective::Cls, &merged.flat, task, 0, 2)?;
            let (_, base_acc) = rt.eval_many(arch, Objective::Cls, &base.flat, task, 0, 2)?;
            println!("  merged accuracy on {task}: {acc:.3} (base was {base_acc:.3})");
        }
    }

    // Alice + Carol: both touched the classification head → conflict.
    let out = merge(spec, &dag, &base, &alice, &carol)?;
    println!("alice + carol -> {}", out.verdict());
    if let MergeOutcome::Conflict { overlapping } = &out {
        println!("  overlapping layers: {overlapping:?}");
        println!("  manual resolution required (as in a git merge conflict)");
    }

    // Alice + base (no second edit): trivially clean.
    let out = merge(spec, &dag, &base, &alice, &base)?;
    println!("alice + noop  -> {}", out.verdict());
    Ok(())
}
