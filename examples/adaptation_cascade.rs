//! **End-to-end driver** (DESIGN.md §5.2): the full MGit lifecycle on a
//! real (small) workload, proving all three layers compose:
//!
//! 1. build a G2-style adaptation graph by *actually training* an MLM
//!    base + per-task classifiers + perturbed versions through the
//!    AOT-compiled PJRT artifacts (loss curves logged);
//! 2. register accuracy tests; persist everything into the CAS with
//!    delta compression and report the headline compression ratio;
//! 3. update the base model (continued pretraining) and run the
//!    **update cascade** (Algorithm 2), reporting per-task accuracy
//!    deltas of cascaded children (Figure-4 analog);
//! 4. run a test bisection over a version chain (§6.4).
//!
//! Results are recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example adaptation_cascade [small]`

use std::path::Path;

use mgit::delta::NativeKernel;
use mgit::lineage::traversal;
use mgit::registry::{CreationSpec, Objective, TestScope, TestSpec};
use mgit::runtime::Runtime;
use mgit::store::Store;
use mgit::train::{CasCheckpointStore, Trainer};
use mgit::update;
use mgit::util::human_secs;
use mgit::util::timing::Timer;
use mgit::workloads::{self, PersistMode, Scale};

fn main() -> anyhow::Result<()> {
    let small = std::env::args().any(|a| a == "small");
    let scale = if small {
        Scale::small()
    } else {
        Scale { n_tasks: 4, versions_per_task: 3, ..Scale::paper() }
    };
    let rt = Runtime::new(Path::new("artifacts"))?;
    let zoo = rt.zoo().clone();
    let store = Store::in_memory();

    // ---- 1. Build the adaptation graph (real training) -----------------
    let t = Timer::start();
    let mut wl = workloads::build_g2(&rt, &scale)?;
    println!(
        "built G2 graph: {} nodes ({} prov / {} ver edges) in {}",
        wl.graph.len(),
        wl.graph.edge_counts().0,
        wl.graph.edge_counts().1,
        human_secs(t.elapsed_secs())
    );

    // ---- 2. Persist with delta compression; report the ratio ----------
    let t = Timer::start();
    let report = workloads::persist(
        &mut wl,
        &store,
        &zoo,
        &rt,
        PersistMode::Delta(Default::default()),
        |_, _| Ok(true),
    )?;
    println!(
        "persisted {} models: {:.2}x compression ({} -> {}) in {}",
        report.n_models,
        report.ratio(),
        mgit::util::human_bytes(report.raw_bytes),
        mgit::util::human_bytes(report.stored_bytes),
        human_secs(t.elapsed_secs())
    );

    // Register per-type accuracy tests.
    wl.graph.tests.register(
        "finite",
        TestScope::ModelType("tx-tiny".into()),
        TestSpec::FiniteParams,
    )?;

    // Baseline accuracy of each task's latest version on perturbed eval.
    let mut base_acc = Vec::new();
    for tsk in 0..scale.n_tasks {
        let task = format!("task{}", tsk + 1);
        let node = wl.graph.idx(&format!("g2/{task}"))?;
        let latest = wl.graph.latest_version(node);
        let name = wl.graph.node(latest).name.clone();
        let ck = wl.ck(&name)?;
        let (_, acc) = rt.eval_many("tx-tiny", Objective::Cls, &ck.flat, &task, 0, 3)?;
        base_acc.push((task, name, acc));
    }

    // ---- 3. Update the base model; cascade -----------------------------
    let trainer = Trainer::new(&rt);
    let ckstore = CasCheckpointStore {
        store: &store,
        zoo: &zoo,
        kernel: &NativeKernel,
        compress: Some(Default::default()),
        cache: None,
    };
    let m = wl.graph.idx("g2/base-mlm")?;
    let base_ck = wl.ck("g2/base-mlm")?.clone();
    // The update: continue MLM pretraining on a *perturbed* corpus, so
    // robustness can only reach children through the cascade (Figure 4).
    let upd_spec = CreationSpec::Pretrain {
        corpus_seed: 4242,
        steps: scale.pretrain_steps,
        lr: scale.lr,
    };
    let new_ck = {
        use mgit::update::CreationExecutor;
        trainer.execute(&upd_spec, "tx-tiny", &[base_ck])?
    };
    let sm = {
        use mgit::update::CheckpointStore;
        ckstore.save(&new_ck, None)?
    };
    let m_new = wl.graph.add_node("g2/base-mlm@v2", "tx-tiny")?;
    wl.graph.node_mut(m_new).stored = Some(sm);
    wl.graph.add_version_edge(m, m_new)?;

    let t = Timer::start();
    let cascade = update::run_update_cascade(
        &mut wl.graph,
        &ckstore,
        &trainer,
        m,
        m_new,
        |_, _| false,
        |_, _| false,
    )?;
    println!(
        "cascade created {} new versions in {}",
        cascade.new_versions.len(),
        human_secs(t.elapsed_secs())
    );

    // Accuracy delta per task (new latest vs old latest) — Figure-4 shape.
    println!("\ntask       old-model                new-model                Δacc");
    for (task, old_name, old_acc) in &base_acc {
        let node = wl.graph.idx(&format!("g2/{task}"))?;
        let latest = wl.graph.latest_version(node);
        let new_name = wl.graph.node(latest).name.clone();
        let sm = wl.graph.node(latest).stored.clone().unwrap();
        let ck = {
            use mgit::update::CheckpointStore;
            ckstore.load(&sm)?
        };
        let (_, acc) = rt.eval_many("tx-tiny", Objective::Cls, &ck.flat, task, 0, 3)?;
        println!(
            "{task:<10} {old_name:<24} {new_name:<24} {:+.3}",
            acc - old_acc
        );
    }

    // ---- 4. Test bisection over one version chain (§6.4) ---------------
    let chain_node = wl.graph.idx("g2/task1")?;
    let chain = traversal::version_chain(&wl.graph, chain_node);
    let first_bad = chain.len() / 2;
    let fails = |i: usize| {
        // Synthetic regression: versions from the midpoint on "fail".
        chain.iter().position(|&c| c == i).unwrap() >= first_bad
    };
    let (found_b, evals_b) = traversal::bisect_first_failure(&chain, fails);
    let (found_s, evals_s) = traversal::scan_first_failure(&chain, fails);
    assert_eq!(found_b, found_s);
    println!(
        "\nbisection over {}-version chain: {} evals vs {} linear ({:.2}x fewer)",
        chain.len(),
        evals_b,
        evals_s,
        evals_s as f64 / evals_b as f64
    );

    // Loss curves summary (first/last of each trace).
    println!("\nloss traces (first -> last):");
    for (label, trace) in trainer.take_traces().iter().take(6) {
        if let (Some(f), Some(l)) = (trace.losses.first(), trace.losses.last()) {
            println!("  {label:<28} {f:.3} -> {l:.3} ({} steps)", trace.losses.len());
        }
    }
    wl.graph.integrity_check()?;
    println!("\nlineage graph integrity: ok — e2e driver complete");
    Ok(())
}
